//! Table 11 (paper §4.2) + the headline capacity claim, measured on this
//! stack: decode throughput at batch 1..32 for the full vs factored
//! serving configs, alongside the paper's Eq. 10 prediction evaluated both
//! at the paper's Mistral-7B constants (exact reproduction) and at our own
//! measured byte counts.

use anyhow::Result;

use crate::bench::Table;
use crate::coordinator::engine::Engine;
use crate::coordinator::eviction::{EvictionConfig, EvictionPolicy, Evictor};
use crate::coordinator::kvcache::{KvCacheConfig, KvCacheManager};
use crate::coordinator::metrics::ServeReport;
use crate::coordinator::roofline::{self, eq10_speedup, GB};
use crate::coordinator::router::{collect_into, synth_prompt, Router};
use crate::coordinator::sampling::Sampler;
use crate::coordinator::scheduler::{SchedConfig, Scheduler};
use crate::coordinator::sequence::{Priority, Sequence};
use crate::datagen::arrival::{mixed_chat_doc_trace, RequestSpec};
use crate::experiments::common::Opts;
use crate::runtime::{KvQuant, ParamStore, Runtime};
use crate::substrate::rng::Rng;
use crate::substrate::tensor::Tensor;

/// Steady-state decode throughput (tokens/s) at a fixed batch size and
/// prompt length. `pin_tier` forces a fixed arena tier (`Some(max_seq)`
/// reproduces the pre-tiering engine — the benchmark baseline); `None`
/// auto-selects the smallest covering tier.
pub fn decode_throughput_opts(rt: &Runtime, cfg_name: &str, batch: usize,
                              steps: usize, pallas: bool, prompt_len: usize,
                              pin_tier: Option<usize>) -> Result<f64> {
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let params = ParamStore::init(&cfg, 42);
    let mut eng = Engine::new(rt, cfg_name, params, pallas,
                              Sampler::Greedy, 0)?;
    eng.pin_tier = pin_tier;
    let mut rng = Rng::new(1);
    let mut seqs: Vec<Sequence> = (0..batch)
        .map(|i| {
            Sequence::new(i as u64 + 1,
                          synth_prompt(prompt_len, cfg.vocab, &mut rng),
                          steps + 8, None)
        })
        .collect();
    for s in seqs.iter_mut() {
        eng.prefill(s)?;
    }
    // warmup (compile + first regroup)
    for _ in 0..3 {
        let mut refs: Vec<&mut Sequence> = seqs.iter_mut().collect();
        eng.decode_step(&mut refs)?;
    }
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        let mut refs: Vec<&mut Sequence> = seqs.iter_mut().collect();
        eng.decode_step(&mut refs)?;
    }
    let secs = t0.elapsed().as_secs_f64();
    Ok((batch * steps) as f64 / secs)
}

/// Steady-state decode throughput (tokens/s) at a fixed batch size.
pub fn decode_throughput(rt: &Runtime, cfg_name: &str, batch: usize,
                         steps: usize, pallas: bool) -> Result<f64> {
    decode_throughput_opts(rt, cfg_name, batch, steps, pallas, 32, None)
}

/// Before/after the context-tiered arena grid, at short contexts: the
/// pre-tiering engine sizes every decode arena at `max_seq` (pinned
/// tier), the tiered engine at the smallest tier covering the live
/// context. This is where Eq. 10's bytes-per-step argument bites — the
/// `servethin` config only shows its bandwidth win once the coordinator
/// stops moving max_seq-sized arenas.
pub fn tiered_decode_table(rt: &Runtime, opts: &Opts) -> Result<Table> {
    let steps = opts.steps(30);
    let mut t = Table::new(
        "Decode throughput at short context (prompt 16, B=4): \
         max_seq arenas (before) vs context-tiered arenas (after)",
        &["config", "pinned max_seq tok/s", "tiered tok/s", "speedup"],
    );
    for cfg_name in ["servefull", "servethin"] {
        let max_seq = rt.manifest().config(cfg_name)?.max_seq;
        let before = decode_throughput_opts(
            rt, cfg_name, 4, steps, false, 16, Some(max_seq))?;
        let after = decode_throughput_opts(
            rt, cfg_name, 4, steps, false, 16, None)?;
        t.row(&[
            cfg_name.to_string(),
            format!("{before:.1}"),
            format!("{after:.1}"),
            format!("{:.2}x", after / before),
        ]);
    }
    Ok(t)
}

/// Mixed-length serving scenario: a short-chat + long-document arrival
/// mix — the workload where context tiering pays off. Reports per-tier
/// occupancy of the (bucket × tier) artifact grid and the host-transfer
/// byte counters (uploads only on membership changes, zero full-arena
/// downloads, O(L·B) delta rows per step).
pub fn mixed_length_table(rt: &Runtime, cfg_name: &str) -> Result<Table> {
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let params = ParamStore::init(&cfg, 42);
    let eng = Engine::new(rt, cfg_name, params, false, Sampler::Greedy, 0)?;
    let kv = KvCacheManager::new(KvCacheConfig {
        n_layers: cfg.n_layers,
        k_dims: cfg.k_cache_dims,
        v_dims: cfg.v_cache_dims,
        block_tokens: 16,
        bytes_per_el_k: 2.0,
        bytes_per_el_v: 2.0,
        budget_bytes: 4e6,
    });
    let sched = Scheduler::new(eng, kv, 16);
    let mut router = Router::new(sched);
    // 12 short chats interleaved with 4 long documents
    let trace: Vec<RequestSpec> = (0..16)
        .map(|i| {
            let doc = i % 4 == 3;
            RequestSpec {
                arrive_s: 0.0,
                prompt_len: if doc { 96 } else { 12 },
                gen_len: if doc { 24 } else { 8 },
                priority: if doc { Priority::Batch }
                          else { Priority::Interactive },
            }
        })
        .collect();
    let report = router.run_closed_loop(&trace, 0)?;
    let m = &router.sched.engine.metrics;
    let mut t = Table::new(
        &format!(
            "Mixed-length serving ({cfg_name}): 12 chats (12+8) + 4 docs \
             (96+24), max_seq {}",
            cfg.max_seq
        ),
        &["metric", "value"],
    );
    for (tier, steps) in &m.tier_steps {
        t.row(&[
            format!("decode steps @ tier n={tier}"),
            format!("{steps} ({:.0}%)",
                    100.0 * *steps as f64 / m.decode_steps as f64),
        ]);
    }
    t.row(&["tier switches".into(), m.tier_switches.to_string()]);
    t.row(&["arena bytes (final)".into(), m.arena_bytes.to_string()]);
    t.row(&["host→device upload B".into(), m.sync_upload_bytes.to_string()]);
    t.row(&["device→host full-arena B".into(),
            m.sync_download_bytes.to_string()]);
    t.row(&["delta-sync B/step".into(),
            format!("{:.0}", m.row_sync_bytes_per_step())]);
    t.row(&["gen tok/s".into(),
            format!("{:.1}", report.gen_tokens_per_sec())]);
    Ok(t)
}

/// One mixed chat+doc run at a given prefill mode. Returns the serve
/// report plus (prefill_chunks, chunk_stall_steps) from the engine.
fn mixed_run(rt: &Runtime, cfg_name: &str, chunk: Option<usize>,
             round_budget: usize) -> Result<(ServeReport, u64, u64)> {
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let params = ParamStore::init(&cfg, 42);
    let eng = Engine::new(rt, cfg_name, params, false, Sampler::Greedy, 0)?;
    let kv = KvCacheManager::new(KvCacheConfig {
        n_layers: cfg.n_layers,
        k_dims: cfg.k_cache_dims,
        v_dims: cfg.v_cache_dims,
        block_tokens: 16,
        bytes_per_el_k: 2.0,
        bytes_per_el_v: 2.0,
        budget_bytes: 4e6,
    });
    let sched = Scheduler::with_config(eng, kv, SchedConfig {
        max_batch: 16,
        round_budget,
        chunk_tokens: chunk,
        interactive_weight: 4,
        ..SchedConfig::default()
    });
    let mut router = Router::new(sched);
    // warmup: compile the prefill path (monolithic or chunked) and the
    // small decode buckets outside the measured trace
    let warmup = vec![
        RequestSpec { arrive_s: 0.0, prompt_len: 120, gen_len: 2,
                      priority: Priority::Batch },
        RequestSpec { arrive_s: 0.0, prompt_len: 8, gen_len: 2,
                      priority: Priority::Interactive },
    ];
    router.run_closed_loop(&warmup, 7)?;
    router.sched.finished.clear();
    let (chunks0, stalls0) = {
        let m = &router.sched.engine.metrics;
        (m.prefill_chunks, m.chunk_stall_steps)
    };
    // the measured mixed trace: 2 docs at t=0, 12 chats arriving while
    // the documents are still being prefilled
    let trace = mixed_chat_doc_trace(12, 2, 0.002, 0.0005);
    let report = router.run_trace(&trace, 0)?;
    let m = &router.sched.engine.metrics;
    Ok((report, m.prefill_chunks - chunks0, m.chunk_stall_steps - stalls0))
}

/// The chunked-prefill acceptance table (ISSUE 3): the mixed chat+doc
/// trace served with monolithic prefill vs chunked prefill at every
/// exported chunk size. The headline column is interactive decode-TTFT
/// p99 — chats arriving mid-document wait out the whole document prompt
/// monolithically, but at most one chunk boundary with chunking (plus
/// their own prefill, which is itself a single small chunk instead of a
/// full prefill_seq pass). Returns the table and the per-mode
/// `(chunk_tokens, interactive p99 us)` pairs so bench_serving can assert
/// the strict improvement.
pub fn chunked_prefill_table(rt: &Runtime, cfg_name: &str)
    -> Result<(Table, Vec<(Option<usize>, f64)>)> {
    let chunks = rt.manifest().chunks_for(cfg_name);
    let mut t = Table::new(
        &format!(
            "Chunked prefill ({cfg_name}): mixed trace, 2 docs (120+8, \
             batch) + 12 chats (8+8, interactive), round budget 64"
        ),
        &["prefill mode", "interactive TTFT p50/p99 (ms)",
          "batch TTFT p99 (ms)", "gen tok/s", "chunks", "stalled rounds"],
    );
    let mut p99s = Vec::new();
    let mut modes: Vec<Option<usize>> = vec![None];
    modes.extend(chunks.iter().map(|&c| Some(c)));
    for mode in modes {
        let (report, n_chunks, n_stalls) =
            mixed_run(rt, cfg_name, mode, 64)?;
        let p99 = report.ttft_interactive.quantile_us(0.99);
        p99s.push((mode, p99));
        t.row(&[
            match mode {
                None => "monolithic".to_string(),
                Some(c) => format!("chunked c={c}"),
            },
            format!("{:.1} / {:.1}",
                    report.ttft_interactive.quantile_us(0.50) / 1e3,
                    p99 / 1e3),
            format!("{:.1}", report.ttft_batch.quantile_us(0.99) / 1e3),
            format!("{:.1}", report.gen_tokens_per_sec()),
            n_chunks.to_string(),
            n_stalls.to_string(),
        ]);
    }
    Ok((t, p99s))
}

/// One fp32-vs-q8 comparison point, returned alongside the table so
/// bench_serving can assert the acceptance criteria (ISSUE 4).
#[derive(Clone, Copy, Debug)]
pub struct QuantCompare {
    pub fp32_tok_s: f64,
    pub q8_tok_s: f64,
    /// K+V arena payload gauge after the run (the 4x headline).
    pub fp32_arena_bytes: u64,
    pub q8_arena_bytes: u64,
    /// q8 scale-plane gauge (0 for fp32) — the honest overhead line.
    pub q8_scale_bytes: u64,
    pub fp32_row_sync_per_step: f64,
    pub q8_row_sync_per_step: f64,
    /// Teacher-forced max-abs-logit error of the q8 engine vs fp32.
    pub max_abs_logit_err: f64,
}

/// Teacher-forced twin decode: run the fp32 and q8 engines over the SAME
/// prompts and force the q8 engine to follow the fp32 engine's sampled
/// tokens, so both attend identical contexts every step; the max abs
/// difference of their per-step logits is then pure quantization error
/// (arena codes + fused dequant), not divergence drift.
pub fn q8_decode_logit_error(rt: &Runtime, cfg_name: &str, batch: usize,
                             steps: usize) -> Result<f64> {
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let params = ParamStore::init(&cfg, 42);
    let mut e32 = Engine::new(rt, cfg_name, params.clone(), false,
                              Sampler::Greedy, 0)?;
    let mut e8 = Engine::with_kv_quant(rt, cfg_name, params, false,
                                       Sampler::Greedy, 0, KvQuant::Q8)?;
    let mut rng = Rng::new(11);
    let prompts: Vec<Vec<i32>> = (0..batch)
        .map(|_| synth_prompt(12, cfg.vocab, &mut rng))
        .collect();
    let mk = |prompts: &[Vec<i32>]| -> Vec<Sequence> {
        prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                Sequence::new(i as u64 + 1, p.clone(), steps + 8, None)
            })
            .collect()
    };
    let mut s32 = mk(&prompts);
    let mut s8 = mk(&prompts);
    for s in s32.iter_mut() {
        e32.prefill(s)?;
    }
    for s in s8.iter_mut() {
        e8.prefill(s)?;
    }
    // align the first generated token (prefill sampling is greedy off
    // fp32 logits in e32 and fp32-prefill logits in e8 — identical, but
    // force anyway so a flip cannot desynchronize the contexts)
    for (a, b) in s32.iter().zip(s8.iter_mut()) {
        *b.generated.last_mut().unwrap() = *a.generated.last().unwrap();
    }
    let mut worst = 0f64;
    for _ in 0..steps {
        let mut r32: Vec<&mut Sequence> = s32.iter_mut().collect();
        e32.decode_step(&mut r32)?;
        drop(r32);
        let mut r8: Vec<&mut Sequence> = s8.iter_mut().collect();
        e8.decode_step(&mut r8)?;
        drop(r8);
        let l32 = e32.last_decode_logits().expect("fp32 logits");
        let l8 = e8.last_decode_logits().expect("q8 logits");
        worst = worst.max(l32.max_abs_diff(l8) as f64);
        // teacher-force: the q8 engine continues from the fp32 tokens
        for (a, b) in s32.iter().zip(s8.iter_mut()) {
            *b.generated.last_mut().unwrap() = *a.generated.last().unwrap();
        }
    }
    Ok(worst)
}

/// The ISSUE 4 acceptance table: the mixed chat+doc trace served by the
/// fp32 engine vs the q8 engine — decode throughput, arena payload and
/// scale gauges, per-step delta-sync traffic, and the teacher-forced
/// max-abs-logit error. The K+V payload shrinks exactly 4x at identical
/// (bucket, tier) trajectories; the scale planes are reported separately
/// so the ~3.6x *total* (payload+scales at toy KD) stays visible next to
/// the 4x payload headline that holds at production widths.
pub fn quantized_decode_table(rt: &Runtime, cfg_name: &str)
    -> Result<(Table, QuantCompare)> {
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let mut per_mode = Vec::new();
    for quant in [KvQuant::Fp32, KvQuant::Q8] {
        let params = ParamStore::init(&cfg, 42);
        let eng = Engine::with_kv_quant(rt, cfg_name, params, false,
                                        Sampler::Greedy, 0, quant)?;
        // model the admission budget at the true per-element widths (the
        // split-pool manager already supports fractional widths): q8
        // amortizes its per-row scale over the row's elements
        let scale_amort_k = quant.scale_bytes_per_row() as f64
            / cfg.k_cache_dims as f64;
        let scale_amort_v = quant.scale_bytes_per_row() as f64
            / cfg.v_cache_dims as f64;
        let kv = KvCacheManager::new(KvCacheConfig {
            n_layers: cfg.n_layers,
            k_dims: cfg.k_cache_dims,
            v_dims: cfg.v_cache_dims,
            block_tokens: 16,
            bytes_per_el_k: quant.elem_bytes() as f64 + scale_amort_k,
            bytes_per_el_v: quant.elem_bytes() as f64 + scale_amort_v,
            budget_bytes: 4e6,
        });
        let sched = Scheduler::new(eng, kv, 16);
        let mut router = Router::new(sched);
        let trace: Vec<RequestSpec> = (0..16)
            .map(|i| {
                let doc = i % 4 == 3;
                RequestSpec {
                    arrive_s: 0.0,
                    prompt_len: if doc { 96 } else { 12 },
                    gen_len: if doc { 24 } else { 8 },
                    priority: if doc { Priority::Batch }
                              else { Priority::Interactive },
                }
            })
            .collect();
        let report = router.run_closed_loop(&trace, 0)?;
        let m = router.sched.engine.metrics.clone();
        per_mode.push((quant, report, m));
    }
    let err = q8_decode_logit_error(rt, cfg_name, 4, 16)?;
    let mut t = Table::new(
        &format!(
            "Quantized decode ({cfg_name}): mixed 12-chat + 4-doc trace, \
             fp32 vs q8 engine (teacher-forced max-abs-logit err \
             {err:.2e})"
        ),
        &["kv quant", "gen tok/s", "arena payload B", "scale B",
          "delta B/step", "sync up B", "down B"],
    );
    for (quant, report, m) in &per_mode {
        t.row(&[
            quant.name().to_string(),
            format!("{:.1}", report.gen_tokens_per_sec()),
            m.arena_bytes.to_string(),
            m.arena_scale_bytes.to_string(),
            format!("{:.0}", m.row_sync_bytes_per_step()),
            m.sync_upload_bytes.to_string(),
            m.sync_download_bytes.to_string(),
        ]);
    }
    let (_, r32, m32) = &per_mode[0];
    let (_, r8, m8) = &per_mode[1];
    let cmp = QuantCompare {
        fp32_tok_s: r32.gen_tokens_per_sec(),
        q8_tok_s: r8.gen_tokens_per_sec(),
        fp32_arena_bytes: m32.arena_bytes,
        q8_arena_bytes: m8.arena_bytes,
        q8_scale_bytes: m8.arena_scale_bytes,
        fp32_row_sync_per_step: m32.row_sync_bytes_per_step(),
        q8_row_sync_per_step: m8.row_sync_bytes_per_step(),
        max_abs_logit_err: err,
    };
    Ok((t, cmp))
}

/// The measured composed-compression summary (ISSUE 5), returned next to
/// the table so the benches can assert the acceptance criteria off the
/// engine gauges rather than the analytic formulas.
#[derive(Clone, Copy, Debug)]
pub struct GqaCompare {
    /// servefull-fp32 K-arena payload gauge / servegqathin-q8 K-arena
    /// payload gauge, at identical (bucket, tier) — the measured
    /// group × rank × element-width composition (64x at this geometry).
    pub composed_key_compression: f64,
    /// Same ratio with the q8 per-row K scale plane charged to the
    /// denominator — the honest number at toy widths (still ≥ 15x).
    pub composed_key_compression_with_scales: f64,
    /// servefull-fp32 vs servegqa-fp32 K gauges: the pure group factor.
    pub group_key_compression: f64,
    /// Teacher-forced max-abs-logit error of the servegqathin q8 engine
    /// vs its fp32 twin (grouped arenas + fused dequant).
    pub gqa_thin_q8_logit_err: f64,
}

/// Run a fixed decode workload and return the engine metrics + tok/s.
/// Every config/quant mode is driven through the SAME (batch, prompt,
/// steps) trajectory, so bucket and tier match across runs and the arena
/// gauges are directly comparable.
fn measured_arena_run(rt: &Runtime, cfg_name: &str, quant: KvQuant,
                      batch: usize, prompt_len: usize, steps: usize)
    -> Result<(crate::coordinator::metrics::EngineMetrics, f64)> {
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let params = ParamStore::init(&cfg, 42);
    let mut eng = Engine::with_kv_quant(rt, cfg_name, params, false,
                                        Sampler::Greedy, 0, quant)?;
    let mut rng = Rng::new(2);
    let mut seqs: Vec<Sequence> = (0..batch)
        .map(|i| {
            Sequence::new(i as u64 + 1,
                          synth_prompt(prompt_len, cfg.vocab, &mut rng),
                          steps + 8, None)
        })
        .collect();
    for s in seqs.iter_mut() {
        eng.prefill(s)?;
    }
    for _ in 0..2 {
        let mut refs: Vec<&mut Sequence> = seqs.iter_mut().collect();
        eng.decode_step(&mut refs)?;
    }
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        let mut refs: Vec<&mut Sequence> = seqs.iter_mut().collect();
        eng.decode_step(&mut refs)?;
    }
    let secs = t0.elapsed().as_secs_f64();
    Ok((eng.metrics.clone(), (batch * steps) as f64 / secs))
}

/// THE measured composition table (ISSUE 5): the serve grid's four
/// configs × kv-quant modes driven through an identical decode workload,
/// with the composed key-cache compression read off the engine's
/// `arena_k_bytes` gauge — the runtime twin of the analytic §6 table in
/// roofline.rs. servegqathin-q8 holds a K arena 64x (payload; 32x with
/// its scale plane) below servefull-fp32 at the same (bucket, tier),
/// with grouped decode logits staying teacher-forced-bounded vs fp32.
pub fn gqa_composition_table(rt: &Runtime)
    -> Result<(Table, GqaCompare)> {
    let (batch, prompt, steps) = (4usize, 16usize, 10usize);
    let modes: [(&str, KvQuant); 6] = [
        ("servefull", KvQuant::Fp32),
        ("servethin", KvQuant::Fp32),
        ("servethin", KvQuant::Q8),
        ("servegqa", KvQuant::Fp32),
        ("servegqathin", KvQuant::Fp32),
        ("servegqathin", KvQuant::Q8),
    ];
    let mut rows = Vec::new();
    for &(cfg_name, quant) in &modes {
        let cfg = rt.manifest().config(cfg_name)?.clone();
        let (m, tok_s) =
            measured_arena_run(rt, cfg_name, quant, batch, prompt, steps)?;
        rows.push((cfg_name, quant, cfg, m, tok_s));
    }
    // all runs follow the same length trajectory over the same tier
    // table, so bucket and tier match across rows and the gauges are
    // directly comparable
    anyhow::ensure!(
        rows.iter().all(|(_, _, _, m, _)| m.arena_k_bytes > 0),
        "arena gauges empty — no regroup happened"
    );
    let err = q8_decode_logit_error(rt, "servegqathin", batch, steps)?;
    let base_k = rows[0].3.arena_k_bytes as f64;
    let mut t = Table::new(
        &format!(
            "Composed key-cache compression, MEASURED off the engine \
             arena gauges (B={batch}, prompt {prompt}, {steps} steps — \
             identical bucket/tier across rows; servegqathin q8-vs-fp32 \
             teacher-forced logit err {err:.2e})"
        ),
        &["config", "kv quant", "KD", "K arena B", "K scale B",
          "K+V arena B", "tok/s", "K compression"],
    );
    for (cfg_name, quant, cfg, m, tok_s) in &rows {
        t.row(&[
            cfg_name.to_string(),
            quant.name().to_string(),
            cfg.k_cache_dims.to_string(),
            m.arena_k_bytes.to_string(),
            m.arena_k_scale_bytes.to_string(),
            m.arena_bytes.to_string(),
            format!("{tok_s:.1}"),
            format!("{:.1}x", base_k / m.arena_k_bytes as f64),
        ]);
    }
    let by = |name: &str, q: KvQuant| {
        rows.iter()
            .find(|(n, rq, ..)| *n == name && *rq == q)
            .map(|(_, _, _, m, _)| m)
            .expect("mode row")
    };
    let gqa8 = by("servegqathin", KvQuant::Q8);
    let cmp = GqaCompare {
        composed_key_compression: base_k / gqa8.arena_k_bytes as f64,
        composed_key_compression_with_scales: base_k
            / (gqa8.arena_k_bytes + gqa8.arena_k_scale_bytes) as f64,
        group_key_compression: base_k
            / by("servegqa", KvQuant::Fp32).arena_k_bytes as f64,
        gqa_thin_q8_logit_err: err,
    };
    Ok((t, cmp))
}

/// Measured decode throughput table (our stack) + measured speedups.
pub fn table11_measured(rt: &Runtime, opts: &Opts) -> Result<Table> {
    let steps = opts.steps(40);
    let batches = [1usize, 2, 4, 8, 16, 32];
    let mut full = Vec::new();
    let mut thin = Vec::new();
    for &b in &batches {
        full.push(decode_throughput(rt, "servefull", b, steps, false)?);
        thin.push(decode_throughput(rt, "servethin", b, steps, false)?);
    }
    let mut t = Table::new(
        "Table 11 (measured, this stack) — decode throughput tok/s",
        &["batch", "full d_k=8", "factored d_k=2", "speedup"],
    );
    for (i, &b) in batches.iter().enumerate() {
        t.row(&[
            b.to_string(),
            format!("{:.1}", full[i]),
            format!("{:.1}", thin[i]),
            format!("{:.2}x", thin[i] / full[i]),
        ]);
    }
    Ok(t)
}

/// The paper's predicted rows, reproduced exactly from Eq. 10 at the
/// published Mistral-7B constants.
pub fn table11_predicted() -> Table {
    let mut t = Table::new(
        "Table 11 (predicted, Eq. 10 @ Mistral-7B constants)",
        &["variant", "b=1", "b=4", "b=8", "b=16", "b=32", "asymptote"],
    );
    let w = roofline::MISTRAL.w_gb * GB;
    let ck = roofline::MISTRAL.ckv_mb * 1e6;
    for (label, w_thin, ck_thin) in roofline::mistral_thin_variants() {
        let (wt, ckt) = (w_thin * GB, ck_thin * 1e6);
        let cells: Vec<String> = [1.0, 4.0, 8.0, 16.0, 32.0]
            .iter()
            .map(|&b| format!("{:.2}x", eq10_speedup(w, wt, ck, ckt, b)))
            .collect();
        t.row(&[
            label.to_string(),
            cells[0].clone(),
            cells[1].clone(),
            cells[2].clone(),
            cells[3].clone(),
            cells[4].clone(),
            format!("{:.2}x", roofline::eq10_asymptote(ck, ckt)),
        ]);
    }
    t
}

/// Copy-back cost of a steady-state membership change: group 8 sequences
/// (B=8), retire one, keep decoding. Reports the host bytes the
/// incremental lane-stable repack moved against what the full
/// park/unpark baseline would have moved — the serving-side companion to
/// the paper's Table 12 copy-back experiment.
pub fn regroup_copyback_table(rt: &Runtime, cfg_name: &str) -> Result<Table> {
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let params = ParamStore::init(&cfg, 42);
    let mut eng = Engine::new(rt, cfg_name, params, false,
                              Sampler::Greedy, 0)?;
    let mut rng = Rng::new(4);
    let mut seqs: Vec<Sequence> = (0..8)
        .map(|i| {
            let max_new = if i == 0 { 2 } else { 12 };
            Sequence::new(i as u64 + 1,
                          synth_prompt(16, cfg.vocab, &mut rng),
                          max_new, None)
        })
        .collect();
    for s in seqs.iter_mut() {
        eng.prefill(s)?;
    }
    // decode at B=8 until the short sequence retires
    while !seqs[0].is_finished() {
        let mut refs: Vec<&mut Sequence> =
            seqs.iter_mut().filter(|s| !s.is_finished()).collect();
        eng.decode_step(&mut refs)?;
    }
    let group_actual = eng.metrics.copyback_bytes;
    let group_full = eng.metrics.copyback_bytes_full;
    eng.drop_seq(seqs[0].id);
    // steady state with the vacated lane
    for _ in 0..4 {
        let mut refs: Vec<&mut Sequence> =
            seqs.iter_mut().filter(|s| !s.is_finished()).collect();
        eng.decode_step(&mut refs)?;
    }
    let retire_actual = eng.metrics.copyback_bytes - group_actual;
    let retire_full = eng.metrics.copyback_bytes_full - group_full;
    let savings = |a: u64, f: u64| {
        if a == 0 {
            "all".to_string()
        } else {
            format!("{:.1}x", f as f64 / a as f64)
        }
    };
    let mut t = Table::new(
        "Regroup copy-back, incremental vs full park/unpark (B=8)",
        &["membership change", "incremental B", "full-repack B", "saved"],
    );
    t.row(&[
        "initial group (8 joins)".into(),
        group_actual.to_string(),
        group_full.to_string(),
        savings(group_actual, group_full),
    ]);
    t.row(&[
        "one retirement, steady state".into(),
        retire_actual.to_string(),
        retire_full.to_string(),
        savings(retire_actual, retire_full),
    ]);
    Ok(t)
}

/// What one shared-prefix cohort run measured (ISSUE 8). Outputs are the
/// per-user generated token streams in submission order, so the caller
/// can assert bit-exactness across sharing modes.
#[derive(Clone, Debug)]
pub struct PrefixRunStats {
    pub report: ServeReport,
    /// Prompt tokens the engine actually computed (prefix hits skip
    /// their adopted rows — with sharing this approaches the UNIQUE
    /// token count of the cohort).
    pub prefill_tokens: u64,
    pub prefix_hits: u64,
    pub prefix_hit_tokens: u64,
    pub cow_splits: u64,
    /// Peak of the dedup-bytes gauge over the run (the end-state gauge
    /// is 0 — a drained pool shares nothing).
    pub peak_dedup_bytes: f64,
    pub peak_shared_blocks: u64,
    /// Most sequences concurrently holding reservations (running +
    /// in-flight prefills) — the capacity headline on a fixed pool.
    pub peak_concurrent: usize,
    pub audit_checks: u64,
    pub sync_download_bytes: u64,
    pub outputs: Vec<Vec<i32>>,
}

/// Serve one chat cohort to completion: `users` sequences over ONE
/// system prompt (`system_tokens` tokens) plus a distinct per-user
/// suffix, on a pool of exactly `pool_blocks` KV blocks. Drives the
/// scheduler directly — router traces synthesize content-free prompts,
/// and prefix sharing is precisely about prompt CONTENT. The same seed
/// generates identical prompts for both sharing modes.
pub fn shared_prefix_run(rt: &Runtime, cfg_name: &str, users: usize,
                         system_tokens: usize, user_tokens: usize,
                         gen_tokens: usize, pool_blocks: usize,
                         sharing: bool) -> Result<PrefixRunStats> {
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let params = ParamStore::init(&cfg, 42);
    let eng = Engine::new(rt, cfg_name, params, false, Sampler::Greedy, 0)?;
    let mut kc = KvCacheConfig {
        n_layers: cfg.n_layers,
        k_dims: cfg.k_cache_dims,
        v_dims: cfg.v_cache_dims,
        block_tokens: 16,
        bytes_per_el_k: 2.0,
        bytes_per_el_v: 2.0,
        budget_bytes: 0.0,
    };
    // size the budget to EXACTLY pool_blocks blocks (plus half a token of
    // float headroom), so both sharing modes compete on the same pool
    kc.budget_bytes = kc.bytes_per_token()
        * (pool_blocks * kc.block_tokens) as f64
        + 0.5 * kc.bytes_per_token();
    let kv = KvCacheManager::new(kc);
    let mut sched = Scheduler::with_config(eng, kv, SchedConfig {
        max_batch: 16,
        prefix_sharing: sharing,
        ..SchedConfig::default()
    });
    let vocab = cfg.vocab;
    let mut rng = Rng::new(23);
    let system = synth_prompt(system_tokens, vocab, &mut rng);
    let t0 = std::time::Instant::now();
    for _ in 0..users {
        let mut prompt = system.clone();
        prompt.extend(synth_prompt(user_tokens, vocab, &mut rng));
        sched.submit(prompt, gen_tokens, None);
    }
    let mut peak_concurrent = 0usize;
    let mut peak_dedup = 0f64;
    let mut peak_shared = 0u64;
    while sched.has_work() {
        sched.step()?;
        peak_concurrent =
            peak_concurrent.max(sched.n_running() + sched.n_prefilling());
        peak_dedup = peak_dedup.max(sched.engine.metrics.dedup_bytes);
        peak_shared = peak_shared.max(sched.engine.metrics.shared_blocks);
    }
    let mut report = ServeReport {
        total_s: t0.elapsed().as_secs_f64(),
        ..ServeReport::default()
    };
    collect_into(&sched.finished, &mut report);
    let mut done = sched.finished;
    done.sort_by_key(|s| s.id);
    let m = &sched.engine.metrics;
    Ok(PrefixRunStats {
        report,
        prefill_tokens: m.prefill_tokens,
        prefix_hits: m.prefix_hits,
        prefix_hit_tokens: m.prefix_hit_tokens,
        cow_splits: m.cow_splits,
        peak_dedup_bytes: peak_dedup,
        peak_shared_blocks: peak_shared,
        peak_concurrent,
        audit_checks: m.audit_checks,
        sync_download_bytes: m.sync_download_bytes,
        outputs: done.into_iter().map(|s| s.generated).collect(),
    })
}

/// A sharing-on vs sharing-off pair at one cohort size, for the
/// acceptance asserts in bench_serving and the e2e suite.
#[derive(Clone, Debug)]
pub struct PrefixCompare {
    pub users: usize,
    pub unique_tokens: u64,
    pub shared: PrefixRunStats,
    pub unshared: PrefixRunStats,
}

impl PrefixCompare {
    pub fn outputs_match(&self) -> bool {
        self.shared.outputs == self.unshared.outputs
    }
}

/// The ISSUE 8 acceptance table: N chat users over one 48-token system
/// prompt, sharing on vs off, on an identical 20-block pool. With
/// sharing, the shared prefix prefills exactly once (prefill tokens ==
/// unique tokens, `prefix_hits == N-1`), the pool holds strictly more
/// concurrent users, and interactive TTFT p50 drops — with outputs
/// bit-exact vs the unshared run.
pub fn shared_prefix_table(rt: &Runtime, cfg_name: &str)
    -> Result<(Table, Vec<PrefixCompare>)> {
    let (system, user, gen, blocks) = (48usize, 8usize, 8usize, 20usize);
    let mut t = Table::new(
        &format!(
            "Shared-prefix serving ({cfg_name}): N users on one \
             {system}-token system prompt, {blocks}-block pool, \
             sharing on vs off"
        ),
        &["users", "mode", "prefill tokens", "prefix hits",
          "peak concurrent", "peak dedup B", "TTFT p50 (ms)", "bit-exact"],
    );
    let mut out = Vec::new();
    for users in [1usize, 8, 64] {
        let shared = shared_prefix_run(
            rt, cfg_name, users, system, user, gen, blocks, true)?;
        let unshared = shared_prefix_run(
            rt, cfg_name, users, system, user, gen, blocks, false)?;
        let cmp = PrefixCompare {
            users,
            unique_tokens: (system + users * user) as u64,
            shared,
            unshared,
        };
        let exact = if cmp.outputs_match() { "yes" } else { "NO" };
        for (mode, r) in [("shared", &cmp.shared),
                          ("unshared", &cmp.unshared)] {
            t.row(&[
                users.to_string(),
                mode.to_string(),
                r.prefill_tokens.to_string(),
                r.prefix_hits.to_string(),
                r.peak_concurrent.to_string(),
                format!("{:.0}", r.peak_dedup_bytes),
                format!("{:.1}",
                        r.report.ttft.quantile_us(0.50) / 1e3),
                exact.to_string(),
            ]);
        }
        out.push(cmp);
    }
    Ok((t, out))
}

/// What one bounded-cache streaming run measured (ISSUE 10 acceptance).
#[derive(Clone, Debug)]
pub struct BoundedStreamStats {
    pub policy: EvictionPolicy,
    /// Requests that completed generation.
    pub completed: usize,
    /// Requests rejected at admission (CacheOverflow) — the acceptance
    /// trace must drive this to `streams` without eviction and 0 with.
    pub rejected: usize,
    /// Peak block-pool occupancy sampled after every scheduler round.
    pub peak_pool_blocks: usize,
    pub pool_blocks: usize,
    pub evicted_blocks: u64,
    pub refused_shared: u64,
    pub capped_admissions: u64,
    pub peak_seq_blocks: u64,
    /// Evicted slots observed inside the sink or the trailing recency
    /// window at ANY sampled round (must stay 0 — pinning is absolute).
    pub pinning_violations: usize,
    pub audit_checks: u64,
    pub sync_download_bytes: u64,
    pub report: ServeReport,
}

/// Serve `streams` infinite-chat streams (8-token prompts, `gen_len`
/// generations) closed-loop on a pool of exactly `pool_blocks` blocks,
/// under `policy`. Each stream's FULL reservation exceeds the pool, so
/// without eviction every stream is rejected at admission; with eviction
/// the capped reservation admits them and the post-decode grow-and-trim
/// pass keeps each stream at its live-block budget. Samples the pool
/// gauge and the pinning invariant after every round (an evicted slot's
/// legality is monotone: rows only grow, so a slot legal at eviction
/// time stays outside the sink and the trailing window forever).
pub fn bounded_stream_run(rt: &Runtime, cfg_name: &str,
                          policy: EvictionPolicy, streams: usize,
                          gen_len: usize, pool_blocks: usize)
    -> Result<BoundedStreamStats> {
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let params = ParamStore::init(&cfg, 42);
    let eng = Engine::new(rt, cfg_name, params, false, Sampler::Greedy, 0)?;
    let kc = KvCacheConfig {
        n_layers: cfg.n_layers,
        k_dims: cfg.k_cache_dims,
        v_dims: cfg.v_cache_dims,
        block_tokens: 16,
        bytes_per_el_k: 2.0,
        bytes_per_el_v: 2.0,
        budget_bytes: 0.0,
    };
    let bt = kc.block_tokens;
    let kv = KvCacheManager::with_block_count(kc, pool_blocks);
    let eviction = EvictionConfig { policy, ..EvictionConfig::default() };
    let mut sched = Scheduler::with_config(eng, kv, SchedConfig {
        max_batch: 8,
        eviction,
        ..SchedConfig::default()
    });
    let mut rng = Rng::new(31);
    let t0 = std::time::Instant::now();
    for _ in 0..streams {
        sched.submit(synth_prompt(8, cfg.vocab, &mut rng), gen_len, None);
    }
    let (sink, window) = (eviction.sink_blocks, eviction.window_blocks);
    let mut peak = 0usize;
    let mut pinning_violations = 0usize;
    let mut stall = 0usize;
    while sched.has_work() {
        let before = sched.finished.len();
        sched.step()?;
        peak = peak.max(sched.kv.stats().k_blocks_used);
        for id in sched.kv.live_seqs() {
            let rows = sched.kv.rows_written(id).unwrap_or(0);
            for e in sched.kv.evicted_slots(id).unwrap_or_default() {
                if e < sink
                    || (e + 1) * bt > rows.saturating_sub(window * bt)
                {
                    pinning_violations += 1;
                }
            }
        }
        if sched.finished.len() == before
            && sched.n_running() == 0
            && !sched.made_progress()
        {
            stall += 1;
            if stall > 2 {
                sched.flush_unservable(stall);
            }
        } else {
            stall = 0;
        }
    }
    let mut report = ServeReport {
        total_s: t0.elapsed().as_secs_f64(),
        ..ServeReport::default()
    };
    collect_into(&sched.finished, &mut report);
    let m = &sched.engine.metrics;
    Ok(BoundedStreamStats {
        policy,
        completed: report.n_requests,
        rejected: report.rejected,
        peak_pool_blocks: peak,
        pool_blocks,
        evicted_blocks: m.eviction.evicted_blocks,
        refused_shared: m.eviction.refused_shared,
        capped_admissions: m.eviction.capped_admissions,
        peak_seq_blocks: m.eviction.peak_seq_blocks,
        pinning_violations,
        audit_checks: m.audit_checks,
        sync_download_bytes: m.sync_download_bytes,
        report,
    })
}

/// The ISSUE 10 acceptance table: the same infinite-chat workload — 4
/// streams whose full 128-token reservations each exceed a 6-block
/// (96-token) pool — under every eviction policy. `none` rejects every
/// stream at admission (the seed behaviour the trace is built to
/// trigger); each active policy completes all of them inside the pool
/// with sink + recency never evicted. Score-ranked policies are skipped
/// (not failed) on legacy manifests without the attn_mass plane.
pub fn eviction_policy_table(rt: &Runtime, cfg_name: &str)
    -> Result<(Table, Vec<BoundedStreamStats>)> {
    let (streams, gen_len, pool) = (4usize, 120usize, 6usize);
    let cfg = rt.manifest().config(cfg_name)?.clone();
    let probe = Engine::new(rt, cfg_name, ParamStore::init(&cfg, 42),
                            false, Sampler::Greedy, 0)?;
    let has_mass = probe.supports_attn_mass();
    drop(probe);
    let mut t = Table::new(
        &format!(
            "Bounded-cache streaming ({cfg_name}): {streams} \
             infinite-chat streams (8+{gen_len} tokens, full reservation \
             8 blocks) on a {pool}-block pool"
        ),
        &["policy", "completed", "rejected", "peak pool blocks",
          "evicted blocks", "refused", "capped adm", "pin viol", "down B"],
    );
    let mut out = Vec::new();
    for policy in [EvictionPolicy::None, EvictionPolicy::Sink,
                   EvictionPolicy::A2sf, EvictionPolicy::Tova] {
        if policy.needs_scores() && !has_mass {
            t.row(&[policy.name().into(), "-".into(), "-".into(),
                    "-".into(), "-".into(), "-".into(), "-".into(),
                    "-".into(), "(no attn_mass plane)".into()]);
            continue;
        }
        let r = bounded_stream_run(rt, cfg_name, policy, streams, gen_len,
                                   pool)?;
        t.row(&[
            policy.name().into(),
            r.completed.to_string(),
            r.rejected.to_string(),
            format!("{}/{}", r.peak_pool_blocks, r.pool_blocks),
            r.evicted_blocks.to_string(),
            r.refused_shared.to_string(),
            r.capped_admissions.to_string(),
            r.pinning_violations.to_string(),
            r.sync_download_bytes.to_string(),
        ]);
        out.push(r);
    }
    Ok((t, out))
}

/// Thin-vs-full eviction-score fidelity (ISSUE 10): do the factored
/// r-dim keys rank eviction victims the way full d-dim keys would?
#[derive(Clone, Debug)]
pub struct ScoreFidelity {
    /// Spearman rank correlation of the thin vs full A2SF slot scores
    /// over the evictable middle.
    pub spearman: f64,
    /// Evictable middle slots both orderings ranked.
    pub slots: usize,
    /// Victims (bottom-k slots) the two orderings agree on, out of `k`.
    pub victim_overlap: usize,
    pub k: usize,
    /// Teacher-forced max-abs logit delta (vs the unevicted baseline)
    /// after evicting the FULL ordering's victims in the full engine.
    pub full_order_delta: f64,
    /// Same delta after evicting the THIN ordering's victims instead —
    /// the cost of selecting by r-dim scores. Fidelity holds when this
    /// tracks `full_order_delta` closely.
    pub thin_order_delta: f64,
}

/// Average-rank helper for Spearman: ranks with ties sharing their mean.
fn avg_ranks(x: &[f64]) -> Vec<f64> {
    let n = x.len();
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&i, &j| {
        x[i].partial_cmp(&x[j]).unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut ranks = vec![0.0; n];
    let mut i = 0usize;
    while i < n {
        let mut j = i;
        while j + 1 < n && x[idx[j + 1]] == x[idx[i]] {
            j += 1;
        }
        let mean = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            ranks[k] = mean;
        }
        i = j + 1;
    }
    ranks
}

/// Spearman rank correlation (Pearson on average ranks).
fn spearman(a: &[f64], b: &[f64]) -> f64 {
    let (ra, rb) = (avg_ranks(a), avg_ranks(b));
    let n = ra.len() as f64;
    if n < 2.0 {
        return 1.0;
    }
    let (ma, mb) = (ra.iter().sum::<f64>() / n, rb.iter().sum::<f64>() / n);
    let mut num = 0.0;
    let mut da = 0.0;
    let mut db = 0.0;
    for (x, y) in ra.iter().zip(&rb) {
        num += (x - ma) * (y - mb);
        da += (x - ma) * (x - ma);
        db += (y - mb) * (y - mb);
    }
    if da == 0.0 || db == 0.0 {
        1.0
    } else {
        num / (da * db).sqrt()
    }
}

/// Twin teacher-forced decode of `servethin` vs `servefull` over one
/// shared token stream, accumulating A2SF slot scores from each engine's
/// `attn_mass` plane; then a second teacher-forced pass in the FULL
/// engine applying each ordering's bottom-k evictions, measuring the
/// logit delta each selection causes vs an unevicted baseline. The
/// paper's selection claim, measured at the eviction policy layer: thin
/// keys must produce the same victim ranking full keys would.
pub fn score_fidelity(rt: &Runtime, prompt_len: usize, steps: usize,
                      k: usize) -> Result<ScoreFidelity> {
    let full_name = "servefull";
    let thin_name = "servethin";
    let cfg_full = rt.manifest().config(full_name)?.clone();
    let cfg_thin = rt.manifest().config(thin_name)?.clone();
    let mut e_full = Engine::new(rt, full_name,
                                 ParamStore::init(&cfg_full, 42), false,
                                 Sampler::Greedy, 0)?;
    let mut e_thin = Engine::new(rt, thin_name,
                                 ParamStore::init(&cfg_thin, 42), false,
                                 Sampler::Greedy, 0)?;
    anyhow::ensure!(
        e_full.supports_attn_mass() && e_thin.supports_attn_mass(),
        "score_fidelity needs the attn_mass decode plane on both configs"
    );
    let mut rng = Rng::new(17);
    let prompt = synth_prompt(prompt_len, cfg_full.vocab.min(cfg_thin.vocab),
                              &mut rng);
    let mut s_full = Sequence::new(1, prompt.clone(), steps + 8, None);
    let mut s_thin = Sequence::new(1, prompt.clone(), steps + 8, None);
    e_full.prefill(&mut s_full)?;
    e_thin.prefill(&mut s_thin)?;
    *s_thin.generated.last_mut().unwrap() = *s_full.generated.last().unwrap();
    let a2sf = EvictionConfig {
        policy: EvictionPolicy::A2sf,
        ..EvictionConfig::default()
    };
    let mut ev_full = Evictor::new(a2sf);
    let mut ev_thin = Evictor::new(a2sf);
    let bt = 16usize;
    // the replayed token stream: prefill's sampled token + one per step
    let mut tokens = vec![*s_full.generated.last().unwrap()];
    for _ in 0..steps {
        let mut r: Vec<&mut Sequence> = vec![&mut s_full];
        e_full.decode_step(&mut r)?;
        drop(r);
        let mut r: Vec<&mut Sequence> = vec![&mut s_thin];
        e_thin.decode_step(&mut r)?;
        drop(r);
        if let Some(m) = e_full.step_attn_mass(1) {
            let m = m.to_vec();
            ev_full.observe(1, &m, bt);
        }
        if let Some(m) = e_thin.step_attn_mass(1) {
            let m = m.to_vec();
            ev_thin.observe(1, &m, bt);
        }
        *s_thin.generated.last_mut().unwrap() =
            *s_full.generated.last().unwrap();
        tokens.push(*s_full.generated.last().unwrap());
    }
    let rows = prompt_len + steps + 1;
    // the evictable middle under the default pinning (sink 1, window 2),
    // restricted to slots fully written by the PROMPT so the replay pass
    // can evict them right after its first decode step
    let cfg_ev = EvictionConfig::default();
    let window_floor = rows.saturating_sub(cfg_ev.window_blocks * bt);
    let candidates: Vec<usize> = (cfg_ev.sink_blocks..)
        .take_while(|&s| (s + 1) * bt <= window_floor.min(prompt_len))
        .collect();
    anyhow::ensure!(
        candidates.len() >= 2,
        "prompt too short for a rankable middle ({prompt_len} tokens)"
    );
    let score_of = |ev: &Evictor| -> Vec<f64> {
        let acc = ev.acc_scores(1).unwrap_or(&[]);
        candidates
            .iter()
            .map(|&s| acc.get(s).copied().unwrap_or(0.0))
            .collect()
    };
    let (sc_full, sc_thin) = (score_of(&ev_full), score_of(&ev_thin));
    let rho = spearman(&sc_full, &sc_thin);
    let bottom_k = |scores: &[f64]| -> Vec<usize> {
        let mut order: Vec<usize> = (0..candidates.len()).collect();
        order.sort_by(|&i, &j| {
            scores[i]
                .partial_cmp(&scores[j])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(candidates[i].cmp(&candidates[j]))
        });
        order[..k.min(order.len())]
            .iter()
            .map(|&i| candidates[i])
            .collect()
    };
    let (v_full, v_thin) = (bottom_k(&sc_full), bottom_k(&sc_thin));
    let overlap = v_full.iter().filter(|s| v_thin.contains(s)).count();
    // replay pass: three full-config engines teacher-forced along the
    // SAME stream; evictions land after the first decode step (lanes are
    // assigned at the first regroup), victims all inside the prompt
    let run_replay = |victims: Option<&[usize]>| -> Result<Vec<Tensor>> {
        let mut eng = Engine::new(rt, full_name,
                                  ParamStore::init(&cfg_full, 42), false,
                                  Sampler::Greedy, 0)?;
        let mut s = Sequence::new(1, prompt.clone(), steps + 8, None);
        eng.prefill(&mut s)?;
        *s.generated.last_mut().unwrap() = tokens[0];
        let mut logits = Vec::with_capacity(steps);
        for (i, &tok) in tokens[1..].iter().enumerate() {
            let mut r: Vec<&mut Sequence> = vec![&mut s];
            eng.decode_step(&mut r)?;
            drop(r);
            logits.push(
                eng.last_decode_logits().expect("decode logits").clone());
            *s.generated.last_mut().unwrap() = tok;
            if i == 0 {
                if let Some(vs) = victims {
                    for &slot in vs {
                        eng.evict_rows(1, slot * bt, bt)?;
                    }
                }
            }
        }
        Ok(logits)
    };
    let base = run_replay(None)?;
    let by_full = run_replay(Some(&v_full))?;
    let by_thin = run_replay(Some(&v_thin))?;
    // step 0 precedes the evictions (identical by construction) — the
    // delta is over the post-eviction steps
    let delta = |evicted: &[Tensor]| -> f64 {
        base.iter()
            .zip(evicted)
            .skip(1)
            .map(|(a, b)| a.max_abs_diff(b) as f64)
            .fold(0.0, f64::max)
    };
    Ok(ScoreFidelity {
        spearman: rho,
        slots: candidates.len(),
        victim_overlap: overlap,
        k: k.min(candidates.len()),
        full_order_delta: delta(&by_full),
        thin_order_delta: delta(&by_thin),
    })
}

/// The score-fidelity table (ISSUE 10): one row summarizing the
/// thin-vs-full eviction-selection agreement, regenerated by
/// `thinkeys experiments serving` (EXPERIMENTS.md §Eviction holds the
/// committed copy).
pub fn score_fidelity_table(rt: &Runtime)
    -> Result<(Table, ScoreFidelity)> {
    let (prompt, steps, k) = (96usize, 32usize, 2usize);
    let f = score_fidelity(rt, prompt, steps, k)?;
    let mut t = Table::new(
        &format!(
            "Thin-vs-full eviction-score fidelity (A2SF scores, prompt \
             {prompt}, {steps} teacher-forced steps, bottom-{k} victims)"
        ),
        &["metric", "value"],
    );
    t.row(&["Spearman rank corr (thin vs full)".into(),
            format!("{:.3}", f.spearman)]);
    t.row(&["evictable middle slots".into(), f.slots.to_string()]);
    t.row(&["victim-set overlap".into(),
            format!("{}/{}", f.victim_overlap, f.k)]);
    t.row(&["logit delta, evict by FULL scores".into(),
            format!("{:.3e}", f.full_order_delta)]);
    t.row(&["logit delta, evict by THIN scores".into(),
            format!("{:.3e}", f.thin_order_delta)]);
    Ok((t, f))
}
pub fn capacity_table() -> Table {
    let c = crate::coordinator::capacity::headline_comparison(
        crate::coordinator::capacity::H100_NODE_7B);
    let mut t = Table::new(
        "Concurrent-user capacity @ 7B / 128K context (H100 node)",
        &["metric", "value"],
    );
    t.row(&["users (standard KV)".into(), c.users_standard.to_string()]);
    t.row(&["users (thin keys d/4)".into(), c.users_thin.to_string()]);
    t.row(&["admission gain".into(), format!("{:.1}%", c.gain_pct)]);
    t.row(&["KV saved per user".into(),
            format!("{:.1} GB", c.saved_gb_per_user)]);
    t
}

pub fn run(rt: &Runtime, opts: &Opts) -> Result<Vec<Table>> {
    let (chunked, _) = chunked_prefill_table(rt, "servethin")?;
    let (quantized, _) = quantized_decode_table(rt, "servethin")?;
    let (gqa, _) = gqa_composition_table(rt)?;
    let (prefix, _) = shared_prefix_table(rt, "servethin")?;
    let (eviction, _) = eviction_policy_table(rt, "servethin")?;
    let mut tables = vec![
        table11_predicted(),
        table11_measured(rt, opts)?,
        tiered_decode_table(rt, opts)?,
        chunked,
        quantized,
        gqa,
        prefix,
        eviction,
        capacity_table(),
    ];
    // score fidelity needs the attn_mass plane on both serve configs;
    // legacy manifests skip the table rather than failing the suite
    let cfg = rt.manifest().config("servethin")?.clone();
    let probe = Engine::new(rt, "servethin", ParamStore::init(&cfg, 42),
                            false, Sampler::Greedy, 0)?;
    if probe.supports_attn_mass() {
        drop(probe);
        let (fidelity, _) = score_fidelity_table(rt)?;
        tables.push(fidelity);
    }
    Ok(tables)
}
