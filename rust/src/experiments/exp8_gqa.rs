//! Experiment 8 (paper §3.4, Tables 7/8): SVD + QK fine-tuning on the GQA
//! model (the Mistral-7B stand-in) — the pipeline must compose with GQA and
//! show the same ~+2% @ /4 recovery shape as the MHA model, plus downstream
//! probe deltas for compressed-then-finetuned models.

use anyhow::Result;

use crate::bench::Table;
use crate::datagen::probes;
use crate::experiments::common::{self, Opts, LARGE_CORPUS};
use crate::model::surgery;
use crate::runtime::{ParamStore, Runtime};
use crate::train::eval;

pub const PRETRAIN_STEPS: usize = 360;

pub fn base_model(rt: &Runtime, opts: &Opts)
    -> Result<(ParamStore, crate::datagen::corpus::Corpus)> {
    let corpus = common::corpus_for(rt, "tinygqa_ds64", LARGE_CORPUS);
    let pre = common::pretrain_lm(rt, "tinygqa_ds64", &corpus, "base",
                                  opts.steps(PRETRAIN_STEPS), opts.seeds[0])?;
    Ok((pre.params, corpus))
}

/// Table 7: rank sweep with before/after-FT PPL vs identically-FT control.
pub fn table7(rt: &Runtime, opts: &Opts) -> Result<(Table, Vec<(String, ParamStore)>)> {
    let (params, corpus) = base_model(rt, opts)?;
    let full_cfg = rt.manifest().config("tinygqa_ds64")?.clone();
    let ft_steps = opts.steps(140);
    let (b, s) = (full_cfg.train_batch, full_cfg.train_seq);
    let batches = corpus.batches(&corpus.train, b, s, 98);

    let control = common::qk_finetune(rt, "tinygqa_ds64", params.clone(),
                                      ft_steps,
                                      |i| batches[i % batches.len()].clone())?;
    let control_ppl = common::val_ppl(rt, "tinygqa_ds64", &control, &corpus)?;
    let mut keep: Vec<(String, ParamStore)> =
        vec![("control".into(), control)];

    let mut t = Table::new(
        &format!(
            "Table 7 — GQA model (8q/2kv): SVD + QK-FT (control: {:.2})",
            control_ppl
        ),
        &["rank", "before FT", "after FT", "vs control", "K cache saved"],
    );
    for ds in [32usize, 16, 8] {
        let thin_name = format!("tinygqa_ds{ds}");
        let thin_cfg = rt.manifest().config(&thin_name)?.clone();
        let thin = surgery::factor_to_thin(&params, &full_cfg, &thin_cfg)?;
        let before = common::val_ppl(rt, &thin_name, &thin, &corpus)?;
        let tuned = common::qk_finetune(rt, &thin_name, thin, ft_steps,
                                        |i| batches[i % batches.len()].clone())?;
        let after = common::val_ppl(rt, &thin_name, &tuned, &corpus)?;
        t.row(&[
            format!("{} (d_K/{})", ds, 64 / ds),
            common::fmt(before, 2),
            common::fmt(after, 2),
            common::fmt_pct(100.0 * (after - control_ppl) / control_ppl),
            format!("{:.0}%", 100.0 * (1.0 - ds as f64 / 64.0)),
        ]);
        keep.push((thin_name, tuned));
    }
    Ok((t, keep))
}

/// Table 8: downstream probes of compressed+FT models vs the FT control.
pub fn table8(rt: &Runtime, opts: &Opts, models: &[(String, ParamStore)])
    -> Result<Table> {
    let model = common::corpus_model(rt, "tinygqa_ds64");
    let n_items = (100.0 * opts.scale).max(20.0) as usize;
    let mut t = Table::new(
        "Table 8 — downstream probes of SVD-compressed GQA model (+FT)",
        &["probe", "ctrl+FT", "r/2 +FT", "r/4 +FT", "d(r/2)", "d(r/4)"],
    );
    let cfg_of = |name: &str| {
        if name == "control" { "tinygqa_ds64".to_string() } else { name.to_string() }
    };
    for (probe_name, items) in probes::standard_suite(&model, n_items, 4321) {
        let mut acc = Vec::new();
        for (name, params) in
            models.iter().filter(|(n, _)| n != "tinygqa_ds8")
        {
            let cfg = rt.manifest().config(&cfg_of(name))?.clone();
            acc.push(100.0 * eval::probe_accuracy(rt, &cfg, params, &items)?);
        }
        t.row(&[
            probe_name.to_string(),
            format!("{:.1}", acc[0]),
            format!("{:.1}", acc[1]),
            format!("{:.1}", acc[2]),
            format!("{:+.1}", acc[1] - acc[0]),
            format!("{:+.1}", acc[2] - acc[0]),
        ]);
    }
    Ok(t)
}

pub fn run(rt: &Runtime, opts: &Opts) -> Result<Vec<Table>> {
    let (t7, models) = table7(rt, opts)?;
    let t8 = table8(rt, opts, &models)?;
    Ok(vec![t7, t8])
}
