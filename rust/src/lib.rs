//! # thinkeys — "Thin Keys, Full Values" full-stack reproduction
//!
//! A three-layer system reproducing the paper's factored-key KV-cache
//! compression end to end:
//!
//! - **Layer 3 (this crate)**: the serving coordinator — request router,
//!   continuous batcher, paged KV cache with *split thin-K / full-V pools*,
//!   model surgery (truncated-SVD key factoring with query absorption), a
//!   training harness that drives AOT train-step executables, and every
//!   substrate those need (tensors, SVD, RNG, JSON, tokenizers, workload
//!   generators, benchmarking, property testing).
//! - **Layer 2**: JAX model family, lowered once to HLO text by
//!   `python/compile/aot.py` (`make artifacts`).
//! - **Layer 1**: Pallas asymmetric-attention kernels, lowered into the same
//!   HLO (interpret mode; see DESIGN.md §7).
//!
//! Python never runs at request time: the runtime loads `artifacts/*.hlo.txt`
//! through the PJRT C API (`xla` crate) and everything else is rust.

pub mod substrate;
pub mod tokenizer;
pub mod datagen;
pub mod runtime;
pub mod model;
pub mod train;
pub mod coordinator;
pub mod analysis;
pub mod bench;
pub mod proptest;
pub mod experiments;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Root of the artifacts directory (overridable via `THINKEYS_ARTIFACTS`).
pub fn artifacts_dir() -> std::path::PathBuf {
    match std::env::var_os("THINKEYS_ARTIFACTS") {
        Some(p) => std::path::PathBuf::from(p),
        None => {
            // Resolve relative to the crate root so tests/benches work from
            // any CWD inside the repo.
            let mut p = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"));
            p.push("artifacts");
            p
        }
    }
}
