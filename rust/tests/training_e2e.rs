//! Training-pipeline integration at quick budgets: the experiment
//! protocols produce sane, paper-shaped results end to end.

use thinkeys::experiments::common::{self, Opts};
use thinkeys::experiments::{exp1_copyback, exp34_lm_sweep};
use thinkeys::model::surgery;
use thinkeys::runtime::Runtime;

fn runtime() -> Runtime {
    Runtime::new().expect("run `make artifacts` first")
}

#[test]
fn lm_pretrain_improves_over_random_and_caches() {
    let rt = runtime();
    let corpus = common::corpus_for(&rt, "tinylm_ds32", 40_000);
    let pre = common::pretrain_lm(&rt, "tinylm_ds32", &corpus, "testcache",
                                  40, 999).unwrap();
    let ppl = common::val_ppl(&rt, "tinylm_ds32", &pre.params, &corpus)
        .unwrap();
    // random-init PPL is ~vocab (512); 40 steps should cut it well down
    assert!(ppl < 350.0, "ppl {ppl}");
    // second call must hit the checkpoint cache
    let again = common::pretrain_lm(&rt, "tinylm_ds32", &corpus, "testcache",
                                    40, 999).unwrap();
    assert!(again.cached);
    let ppl2 = common::val_ppl(&rt, "tinylm_ds32", &again.params, &corpus)
        .unwrap();
    assert!((ppl - ppl2).abs() < 1e-6);
}

#[test]
fn copyback_learns_above_chance() {
    let rt = runtime();
    let row = exp1_copyback::run_config(&rt, "copyback_ds16", 240, 60, 2e-3,
                                        1).unwrap();
    // chance is 1/16 = 6.25%; 4 dims/head must beat it decisively within
    // a short budget (the full sweep incl. ds4 runs in experiments exp1)
    assert!(row.best_acc > 0.3, "acc {}", row.best_acc);
}

#[test]
fn lm_sweep_rows_are_ordered_reasonably() {
    let rt = runtime();
    let rows = exp34_lm_sweep::sweep(&rt, "small", 30, 7).unwrap();
    assert_eq!(rows.len(), 4);
    // QK savings must be monotone decreasing in d_select
    for w in rows.windows(2) {
        assert!(w[0].qk_saved_pct > w[1].qk_saved_pct);
    }
    assert!(rows.iter().all(|r| r.val_ppl.is_finite() && r.val_ppl > 1.0));
}

#[test]
fn qk_finetune_recovers_factored_model() {
    // After aggressive factoring, a few QK-FT steps must improve PPL.
    let rt = runtime();
    let corpus = common::corpus_for(&rt, "tinylm_ds64", 40_000);
    let pre = common::pretrain_lm(&rt, "tinylm_ds64", &corpus, "testqkft",
                                  60, 998).unwrap();
    let full_cfg = rt.manifest().config("tinylm_ds64").unwrap().clone();
    let thin_cfg = rt.manifest().config("tinylm_ds8").unwrap().clone();
    let thin =
        surgery::factor_to_thin(&pre.params, &full_cfg, &thin_cfg).unwrap();
    let before = common::val_ppl(&rt, "tinylm_ds8", &thin, &corpus).unwrap();
    let batches = corpus.batches(&corpus.train, full_cfg.train_batch,
                                 full_cfg.train_seq, 5);
    let tuned = common::qk_finetune(&rt, "tinylm_ds8", thin, 30,
                                    |i| batches[i % batches.len()].clone())
        .unwrap();
    let after = common::val_ppl(&rt, "tinylm_ds8", &tuned, &corpus).unwrap();
    assert!(after < before, "QK-FT did not help: {before} -> {after}");
}

#[test]
fn opts_quick_is_fast_enough_for_benches() {
    let o = Opts::quick();
    assert!(o.steps(900) <= 90);
    assert_eq!(o.seeds.len(), 1);
}
