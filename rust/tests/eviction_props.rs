//! Bounded-cache eviction invariants under randomized streaming churn
//! (ISSUE 10), seeded through `thinkeys::proptest::property` so a failure
//! reproduces from its printed seed.
//!
//! For every policy (sink / a2sf / tova), random submit/step traffic on a
//! deliberately tiny block pool — every stream's full reservation exceeds
//! it, so admissions are capped and the grow-and-trim pass runs hot —
//! asserting after EVERY scheduler round:
//!
//! - pinning is absolute: no evicted slot inside the sink prefix or the
//!   trailing recency window (legality is monotone — rows only grow — so
//!   a slot legal at eviction time stays legal forever);
//! - the paged accounting balances: used + free == total, and
//!   `KvCacheManager::refcount_violations` is empty (slot conservation,
//!   sorted/unique holes, no evicted slot inside a shared region,
//!   refcounts == table membership — i.e. shared blocks never evicted);
//! - the full engine auditor stays green, including the evicted-rows
//!   ledger reconciliation (`Engine::evicted_rows_of` vs the block
//!   table's holes);
//! - after draining, every block is free again and `audit_checks > 0`
//!   (the audits actually ran).

use thinkeys::analysis::auditor;
use thinkeys::coordinator::engine::Engine;
use thinkeys::coordinator::eviction::{EvictionConfig, EvictionPolicy};
use thinkeys::coordinator::kvcache::{KvCacheConfig, KvCacheManager};
use thinkeys::coordinator::router::synth_prompt;
use thinkeys::coordinator::sampling::Sampler;
use thinkeys::coordinator::scheduler::{SchedConfig, Scheduler};
use thinkeys::proptest::property;
use thinkeys::runtime::{ParamStore, Runtime};
use thinkeys::substrate::rng::Rng;

fn runtime() -> Runtime {
    Runtime::new().expect("run `make artifacts` first")
}

fn engine<'a>(rt: &'a Runtime, cfg: &str, seed: u64) -> Engine<'a> {
    let params = ParamStore::init(rt.manifest().config(cfg).unwrap(), 42);
    Engine::new(rt, cfg, params, false, Sampler::Greedy, seed).unwrap()
}

fn kv_blocks(rt: &Runtime, cfg: &str, blocks: usize) -> KvCacheManager {
    let c = rt.manifest().config(cfg).unwrap();
    KvCacheManager::with_block_count(
        KvCacheConfig {
            n_layers: c.n_layers,
            k_dims: c.k_cache_dims,
            v_dims: c.v_cache_dims,
            block_tokens: 16,
            bytes_per_el_k: 2.0,
            bytes_per_el_v: 2.0,
            budget_bytes: 0.0,
        },
        blocks,
    )
}

/// The per-round invariant bundle. `sink`/`window` echo the eviction
/// config; `bt` is block_tokens.
fn check_round(sched: &Scheduler, sink: usize, window: usize, bt: usize)
    -> Result<(), String> {
    // pinning: no evicted slot in the sink or the trailing window
    for id in sched.kv.live_seqs() {
        let rows = sched.kv.rows_written(id).unwrap_or(0);
        for e in sched.kv.evicted_slots(id).unwrap_or_default() {
            if e < sink {
                return Err(format!(
                    "seq {id}: sink slot {e} evicted (sink = {sink})"
                ));
            }
            if (e + 1) * bt > rows.saturating_sub(window * bt) {
                return Err(format!(
                    "seq {id}: slot {e} inside the {window}-block recency \
                     window at {rows} rows"
                ));
            }
        }
    }
    // pool balance: used + free == total
    let stats = sched.kv.stats();
    let free = sched.kv.free_token_capacity() / bt;
    let total = sched.kv.total_token_capacity() / bt;
    if stats.k_blocks_used + free != total {
        return Err(format!(
            "pool imbalance: {} used + {free} free != {total} total",
            stats.k_blocks_used
        ));
    }
    // block-accounting self-consistency (refcounts, slot conservation,
    // hole ordering, shared regions)
    let v = sched.kv.refcount_violations();
    if !v.is_empty() {
        return Err(format!("refcount violations: {}", v.join("; ")));
    }
    // the full cross-view audit, including the evicted-rows ledger
    let v = auditor::audit(&sched.engine, &sched.kv);
    if !v.is_empty() {
        return Err(format!("auditor violations: {}", v.join("; ")));
    }
    Ok(())
}

fn churn(policy: EvictionPolicy, name: &'static str) {
    let rt = runtime();
    let mut total_evicted = 0u64;
    let mut total_capped = 0u64;
    property(name, 3, |rng| {
        let eng = engine(&rt, "servethin", rng.next_u64());
        // 8-block pool, 4-block per-seq budget: any stream generating
        // past ~56 tokens outgrows its cap and must self-fund
        let kv = kv_blocks(&rt, "servethin", 8);
        let eviction = EvictionConfig {
            policy,
            ..EvictionConfig::default()
        };
        let mut sched = Scheduler::with_config(eng, kv, SchedConfig {
            max_batch: 4,
            round_budget: 48,
            prefix_sharing: rng.below(2) == 0,
            eviction,
            ..SchedConfig::default()
        });
        let bt = 16usize;
        let (sink, window) = (eviction.sink_blocks, eviction.window_blocks);
        let vocab = sched.engine.cfg.vocab;
        let mut submitted = 0usize;
        for _ in 0..30 {
            match rng.below(3) {
                0 if submitted < 10 => {
                    // short prompt, generation long enough that the full
                    // reservation exceeds the 8-block pool half the time
                    let plen = 1 + rng.below(24);
                    let gen = if rng.below(2) == 0 {
                        100 + rng.below(40)
                    } else {
                        4 + rng.below(40)
                    };
                    let prompt = synth_prompt(plen, vocab, rng);
                    sched.submit(prompt, gen, None);
                    submitted += 1;
                }
                _ => {
                    sched.step().map_err(|e| e.to_string())?;
                }
            }
            check_round(&sched, sink, window, bt)?;
        }
        sched.run_to_completion().map_err(|e| e.to_string())?;
        check_round(&sched, sink, window, bt)?;
        if sched.finished.len() != submitted {
            return Err(format!(
                "{submitted} submitted but {} finished",
                sched.finished.len()
            ));
        }
        // drained: the whole pool is free again
        if sched.kv.free_token_capacity() != sched.kv.total_token_capacity()
        {
            return Err("leaked KV blocks after drain".into());
        }
        let m = &sched.engine.metrics;
        if m.audit_checks == 0 {
            return Err("auditor never ran".into());
        }
        if m.sync_download_bytes != 0 {
            return Err(format!(
                "sync_download_bytes = {} under eviction churn",
                m.sync_download_bytes
            ));
        }
        total_evicted += m.eviction.evicted_blocks;
        total_capped += m.eviction.capped_admissions;
        Ok(())
    });
    // across the seeded cases the workload must actually have exercised
    // the machinery, or the invariants above were vacuous
    assert!(total_evicted > 0, "{name}: no block was ever evicted");
    assert!(total_capped > 0, "{name}: no admission was ever capped");
}

#[test]
fn eviction_churn_sink() {
    churn(EvictionPolicy::Sink, "eviction_churn_sink");
}

#[test]
fn eviction_churn_a2sf() {
    churn(EvictionPolicy::A2sf, "eviction_churn_a2sf");
}

#[test]
fn eviction_churn_tova() {
    churn(EvictionPolicy::Tova, "eviction_churn_tova");
}
