//! Scheduler/engine invariants under randomized traffic (ISSUE 3).
//!
//! Three layers, all seeded through `thinkeys::proptest::property` so a
//! failure reproduces from its printed seed:
//!
//! 1. Pure `LaneMap` fuzz (no artifacts): random interleavings of
//!    join / retire / bucket-resize, asserting lane stability for
//!    survivors and assignment consistency after every plan/apply.
//! 2. Scheduler accounting invariants: randomized
//!    submit/step/preempt/finish traffic (both monolithic and chunked
//!    prefill modes), asserting after every event that
//!    `KvCacheManager` mirrors `Engine::rows`, that admission/prefill
//!    failures leak no KV reservation, and that freed blocks and arena
//!    rows always go together.
//! 3. Engine churn fuzz: random join/retire/tier-switch interleavings
//!    against the live engine, asserting lane stability for survivors
//!    and `sync_download_bytes == 0` throughout (extends the PR 2
//!    steady-churn tripwire).

use std::collections::BTreeMap;

use thinkeys::coordinator::engine::Engine;
use thinkeys::coordinator::kvcache::{KvCacheConfig, KvCacheManager};
use thinkeys::coordinator::lanes::LaneMap;
use thinkeys::coordinator::router::synth_prompt;
use thinkeys::coordinator::sampling::Sampler;
use thinkeys::coordinator::scheduler::{SchedConfig, Scheduler};
use thinkeys::coordinator::sequence::{Priority, SeqId, Sequence};
use thinkeys::proptest::property;
use thinkeys::runtime::{ParamStore, Runtime};
use thinkeys::substrate::rng::Rng;

// ---------------------------------------------------------------------------
// 1. Pure LaneMap fuzz — no artifacts needed
// ---------------------------------------------------------------------------

/// Random interleavings of join / retire / resize against `LaneMap`:
/// survivors keep their lanes across any non-resize change, assignments
/// stay bijective, and joins only ever fill holes.
#[test]
fn lane_map_fuzz_random_interleavings() {
    let buckets = [1usize, 2, 4, 8, 16, 32];
    property("lane_map_fuzz", 200, |rng| {
        let mut lm = LaneMap::new();
        let mut live: Vec<SeqId> = Vec::new();
        let mut next_id: SeqId = 1;
        for _ in 0..40 {
            match rng.below(3) {
                // join 1..4 new sequences
                0 => {
                    let n = 1 + rng.below(4);
                    for _ in 0..n {
                        if live.len() >= 32 {
                            break;
                        }
                        live.push(next_id);
                        next_id += 1;
                    }
                }
                // retire a random live sequence (zero-copy hole)
                1 if !live.is_empty() => {
                    let idx = rng.below(live.len());
                    let id = live.swap_remove(idx);
                    if lm.lane_of(id).is_some() && !lm.remove(id) {
                        return Err(format!("remove({id}) lost a lane"));
                    }
                }
                _ => {}
            }
            let bucket = buckets
                .iter()
                .copied()
                .find(|&b| b >= live.len())
                .unwrap();
            // sometimes keep a larger bucket (hysteresis-style), so plans
            // exercise both resize and in-place paths
            let bucket = if rng.below(2) == 0 {
                bucket.max(lm.bucket().min(32))
            } else {
                bucket
            };
            let before: BTreeMap<SeqId, usize> = live
                .iter()
                .filter_map(|&id| lm.lane_of(id).map(|l| (id, l)))
                .collect();
            let plan = lm.plan(&live, bucket);
            let resized = plan.resize;
            lm.apply(&plan);
            // bijectivity: every live id has exactly one lane < bucket
            let mut seen = vec![false; bucket];
            for &id in &live {
                let Some(lane) = lm.lane_of(id) else {
                    return Err(format!("live {id} lost its lane"));
                };
                if lane >= bucket {
                    return Err(format!("lane {lane} >= bucket {bucket}"));
                }
                if seen[lane] {
                    return Err(format!("lane {lane} double-assigned"));
                }
                seen[lane] = true;
            }
            if lm.live() != live.len() {
                return Err(format!(
                    "live {} != expected {}", lm.live(), live.len()));
            }
            // lane stability: without a resize, survivors never move
            if !resized {
                for (&id, &lane) in &before {
                    if lm.lane_of(id) != Some(lane) {
                        return Err(format!(
                            "survivor {id} moved {lane} -> {:?} \
                             without a resize", lm.lane_of(id)));
                    }
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Shared harness for the artifact-backed layers
// ---------------------------------------------------------------------------

fn runtime() -> Runtime {
    Runtime::new().expect("run `make artifacts` first")
}

fn engine<'a>(rt: &'a Runtime, cfg: &str, seed: u64) -> Engine<'a> {
    let params = ParamStore::init(rt.manifest().config(cfg).unwrap(), 42);
    Engine::new(rt, cfg, params, false, Sampler::Greedy, seed).unwrap()
}

fn kv_for(rt: &Runtime, cfg: &str, budget_mb: f64) -> KvCacheManager {
    let c = rt.manifest().config(cfg).unwrap();
    KvCacheManager::new(KvCacheConfig {
        n_layers: c.n_layers,
        k_dims: c.k_cache_dims,
        v_dims: c.v_cache_dims,
        block_tokens: 16,
        bytes_per_el_k: 2.0,
        bytes_per_el_v: 2.0,
        budget_bytes: budget_mb * 1e6,
    })
}

// ---------------------------------------------------------------------------
// 2. Scheduler accounting invariants under randomized traffic
// ---------------------------------------------------------------------------

/// The unified-accounting contract, checked after EVERY event:
/// - every admitted sequence (running or mid-chunked-prefill) has a block
///   table whose `rows_written` mirrors `Engine::rows` exactly;
/// - the block tables cover exactly the admitted sequences — a failed
///   admission or prefill leaves no reservation behind;
/// - after draining, every block and every arena row is free again
///   (freed blocks and arena rows always go together).
fn check_accounting(sched: &Scheduler) -> Result<(), String> {
    let stats = sched.kv.stats();
    let admitted = sched.n_running() + sched.n_prefilling();
    if stats.seqs != admitted {
        return Err(format!(
            "kv tracks {} seqs, scheduler has {admitted} admitted",
            stats.seqs
        ));
    }
    let mut written = 0usize;
    for id in 1..=64u64 {
        match sched.kv.rows_written(id) {
            Some(rows) => {
                if rows != sched.engine.rows(id) {
                    return Err(format!(
                        "seq {id}: kv mirror {rows} != engine rows {}",
                        sched.engine.rows(id)
                    ));
                }
                written += rows;
            }
            None => {
                if sched.engine.rows(id) != 0 {
                    return Err(format!(
                        "seq {id}: engine holds {} rows with no kv table",
                        sched.engine.rows(id)
                    ));
                }
            }
        }
    }
    if stats.tokens_written != written {
        return Err(format!(
            "tokens_written {} != summed mirror {written}",
            stats.tokens_written
        ));
    }
    Ok(())
}

fn random_traffic(chunked: bool) {
    let rt = runtime();
    let chunk = *rt.manifest().chunks_for("servethin").first().unwrap();
    property(
        if chunked { "scheduler_invariants_chunked" }
        else { "scheduler_invariants_monolithic" },
        4,
        |rng| {
            let eng = engine(&rt, "servethin", rng.next_u64());
            // small budget so admission blocking + stall flush both fire
            let kv = kv_for(&rt, "servethin", 0.12);
            let mut sched = Scheduler::with_config(eng, kv, SchedConfig {
                max_batch: 6,
                round_budget: 48,
                chunk_tokens: if chunked { Some(chunk) } else { None },
                interactive_weight: 2,
                ..SchedConfig::default()
            });
            let vocab = sched.engine.cfg.vocab;
            let mut submitted = 0usize;
            for _ in 0..30 {
                match rng.below(4) {
                    0 => {
                        // submit: mostly servable, sometimes a prompt that
                        // exceeds the prefill bucket (PrefillFailed) or a
                        // reservation that can never fit (CacheOverflow)
                        let plen = match rng.below(8) {
                            // exceeds the prefill bucket: PrefillFailed
                            // after admission (reservation rolled back)
                            0 => sched.engine.max_prompt() + 1,
                            // exceeds TOTAL capacity: can never be
                            // admitted, evicted by the stall flush
                            1 => 250,
                            _ => 1 + rng.below(60),
                        };
                        let prio = if rng.below(3) == 0 {
                            Priority::Batch
                        } else {
                            Priority::Interactive
                        };
                        let prompt = synth_prompt(plen, vocab, rng);
                        sched.submit_seq(
                            prompt, 1 + rng.below(6), None, prio, None);
                        submitted += 1;
                    }
                    1 if sched.n_running() > 0 => {
                        let preempted = sched.preempt_one();
                        if preempted.is_none() {
                            return Err("preempt with running seqs".into());
                        }
                    }
                    _ => {
                        sched.step().map_err(|e| e.to_string())?;
                    }
                }
                check_accounting(&sched)?;
            }
            sched.run_to_completion().map_err(|e| e.to_string())?;
            check_accounting(&sched)?;
            // drained: every reservation released, arena rows gone with it
            if sched.kv.stats().seqs != 0 {
                return Err("leaked block tables after drain".into());
            }
            if sched.kv.free_token_capacity()
                != sched.kv.total_token_capacity()
            {
                return Err("leaked KV blocks after drain".into());
            }
            if sched.engine.parked_bytes() != 0 {
                return Err("leaked parked arena rows after drain".into());
            }
            if sched.finished.len() != submitted {
                return Err(format!(
                    "{} submitted but {} finished",
                    submitted,
                    sched.finished.len()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn scheduler_invariants_random_traffic_monolithic() {
    random_traffic(false);
}

#[test]
fn scheduler_invariants_random_traffic_chunked() {
    random_traffic(true);
}

// ---------------------------------------------------------------------------
// 3. Engine churn fuzz — lane stability + the download tripwire
// ---------------------------------------------------------------------------

/// Random interleavings of join / retire / decode against the live
/// engine, with prompt lengths straddling tier boundaries so tier
/// switches and bucket resizes both fire: survivors' lanes never move
/// except across a resize/tier change the plan reports, and the
/// delta-synced mirror never downloads a full arena
/// (`sync_download_bytes == 0`, the PR 2 steady-churn tripwire).
#[test]
fn engine_churn_fuzz_lane_stable_and_download_free() {
    let rt = runtime();
    property("engine_churn_fuzz", 3, |rng| {
        let mut eng = engine(&rt, "servethin", rng.next_u64());
        let vocab = eng.cfg.vocab;
        let mut live: Vec<Sequence> = Vec::new();
        let mut next_id: SeqId = 1;
        for _ in 0..25 {
            match rng.below(3) {
                0 if live.len() < 8 => {
                    // join: prompt length drawn across tier boundaries
                    let plen = 1 + rng.below(100);
                    let mut seq = Sequence::new(
                        next_id,
                        synth_prompt(plen, vocab, rng),
                        2 + rng.below(20),
                        None,
                    );
                    next_id += 1;
                    eng.prefill(&mut seq).map_err(|e| e.to_string())?;
                    live.push(seq);
                }
                1 if !live.is_empty() => {
                    // retire a random live sequence mid-flight
                    let idx = rng.below(live.len());
                    let seq = live.swap_remove(idx);
                    eng.drop_seq(seq.id);
                }
                _ => {}
            }
            if live.is_empty() {
                continue;
            }
            let lanes_before: BTreeMap<SeqId, usize> = live
                .iter()
                .filter_map(|s| eng.lane_of(s.id).map(|l| (s.id, l)))
                .collect();
            let (bucket_before, tier_before) =
                (eng.current_bucket(), eng.current_tier());
            let mut refs: Vec<&mut Sequence> =
                live.iter_mut().filter(|s| !s.is_finished()).collect();
            if refs.is_empty() {
                continue;
            }
            eng.decode_step(&mut refs).map_err(|e| e.to_string())?;
            drop(refs);
            if eng.metrics.sync_download_bytes != 0 {
                return Err(format!(
                    "full-arena download after churn: {} bytes",
                    eng.metrics.sync_download_bytes
                ));
            }
            // lane stability: unless the arena itself was rebuilt (bucket
            // resize or tier switch), survivors never move lanes
            if eng.current_tier() == tier_before
                && eng.current_bucket() == bucket_before
            {
                for s in live.iter().filter(|s| !s.is_finished()) {
                    if let Some(&was) = lanes_before.get(&s.id) {
                        if eng.lane_of(s.id) != Some(was) {
                            return Err(format!(
                                "survivor {} moved lane {was} -> {:?} \
                                 without a resize or tier switch",
                                s.id,
                                eng.lane_of(s.id)
                            ));
                        }
                    }
                }
            }
            // retire finished sequences the way the scheduler does
            let done: Vec<SeqId> = live
                .iter()
                .filter(|s| s.is_finished())
                .map(|s| s.id)
                .collect();
            for id in done {
                eng.drop_seq(id);
                live.retain(|s| s.id != id);
            }
        }
        Ok(())
    });
}
