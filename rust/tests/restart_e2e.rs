//! Restart e2e (ISSUE 9 capstone): the mixed chat+doc churn workload
//! under seeded fatal + wedge fault plans, served by a supervised
//! scheduler (checkpoint every K rounds, warm restart on Fatal or
//! watchdog overrun, deterministic replay).
//!
//! The contracts under test:
//! - a fatal-plan run COMPLETES every request with zero run-ending
//!   escalations inside the restart budget (`engine_restarts > 0`,
//!   `failed == 0`),
//! - replay is BIT-EXACT: per-sequence outputs equal the fault-free
//!   twin's, and the `(logical_round, state_fingerprint)` sequence
//!   recorded at every checkpoint is equal across the two runs — the
//!   cadence counts logical rounds, so restarts realign at 0, K, 2K, …
//! - a wedged execute (latency injection, no error) trips the per-step
//!   watchdog, restarts, and still decodes the fault-free tokens,
//! - recovery re-uploads device state from the host mirrors only
//!   (`sync_download_bytes == 0` throughout),
//! - mid-prefill fatals leak NO KV reservations (satellite 1: the
//!   admit-blocks-then-fail window frees before requeueing),
//! - a SPENT restart budget drains visibly (shed/failed buckets) and
//!   returns a report instead of crashing the serve loop,
//! - the runtime auditor stays green across every restart.
//!
//! Runs are closed-loop so round composition is deterministic (the
//! fingerprint oracle needs matched rounds). `RESTART_SEED` selects the
//! fault schedule (CI runs two fixed seeds).

use std::collections::BTreeMap;

use thinkeys::coordinator::engine::Engine;
use thinkeys::coordinator::kvcache::{KvCacheConfig, KvCacheManager};
use thinkeys::coordinator::metrics::{EngineMetrics, ServeReport};
use thinkeys::coordinator::router::{
    bucket_of, synth_prompt, ReportBucket, Router,
};
use thinkeys::coordinator::sampling::Sampler;
use thinkeys::coordinator::scheduler::{SchedConfig, Scheduler};
use thinkeys::coordinator::supervisor::{Supervisor, SupervisorConfig};
use thinkeys::datagen::arrival::{mixed_chat_doc_trace, RequestSpec};
use thinkeys::runtime::{FaultPlan, ParamStore, Runtime};
use thinkeys::substrate::rng::Rng;

fn restart_seed() -> u64 {
    std::env::var("RESTART_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(7)
}

/// Supervision knobs shared by every run in this file: checkpoint every
/// 4 rounds (worst-case replay = 4), tight backoff so tests stay fast.
fn sup_cfg() -> SupervisorConfig {
    SupervisorConfig {
        checkpoint_every: 4,
        max_restarts: 8,
        restart_backoff_us: 100,
        max_restart_backoff_us: 5_000,
        watchdog_step_s: None,
    }
}

/// Everything a supervised run leaves behind once the runtime is gone.
struct RestartRun {
    report: ServeReport,
    /// id -> generated tokens, COMPLETED sequences only. Closed-loop
    /// submission order is trace order, so ids line up across runs.
    tokens: BTreeMap<u64, Vec<i32>>,
    metrics: EngineMetrics,
    violations: Vec<String>,
    /// `(logical_round, state_fingerprint)` at every checkpoint — the
    /// replay bit-exactness oracle.
    fingerprints: Vec<(u64, u64)>,
    kv_free_tokens: usize,
    kv_total_tokens: usize,
    refcount_violations: Vec<String>,
}

fn run(
    plan: Option<FaultPlan>,
    sup: Option<SupervisorConfig>,
    prefix_sharing: bool,
    trace: &[RequestSpec],
) -> RestartRun {
    let rt = Runtime::new().expect("run `make artifacts` first");
    if let Some(p) = plan {
        rt.install_fault_plan(p);
    }
    let cfg = "servethin";
    let c = rt.manifest().config(cfg).unwrap().clone();
    let mk_kv = |c: &thinkeys::runtime::ConfigEntry| {
        KvCacheManager::new(KvCacheConfig {
            n_layers: c.n_layers,
            k_dims: c.k_cache_dims,
            v_dims: c.v_cache_dims,
            block_tokens: 16,
            bytes_per_el_k: 2.0,
            bytes_per_el_v: 2.0,
            budget_bytes: 4e6,
        })
    };
    let params = ParamStore::init(&c, 42);
    let eng = Engine::new(&rt, cfg, params, false, Sampler::Greedy, 0).unwrap();
    let chunk = rt.manifest().chunks_for(cfg).first().copied();
    let sched = Scheduler::with_config(eng, mk_kv(&c), SchedConfig {
        max_batch: 8,
        round_budget: 64,
        chunk_tokens: chunk,
        interactive_weight: 4,
        max_step_retries: 4,
        retry_backoff_us: 50,
        prefix_sharing,
        ..SchedConfig::default()
    });
    let mut router = Router::new(sched);
    if let Some(scfg) = sup {
        // the factory rebuilds an engine IDENTICAL to the original (same
        // manifest config, same param seed, same sampler) — the restore
        // target after a Fatal
        let rt_ref = &rt;
        let fact_cfg = c.clone();
        let factory = move || {
            let params = ParamStore::init(&fact_cfg, 42);
            Engine::new(rt_ref, cfg, params, false, Sampler::Greedy, 0)
        };
        router = router.with_supervisor(Supervisor::new(scfg, factory));
    }
    let report = router
        .run_closed_loop(trace, 0)
        .expect("the supervised serve loop must survive its fault plan");
    let mut tokens = BTreeMap::new();
    for seq in &router.sched.finished {
        if bucket_of(seq) == ReportBucket::Completed {
            tokens.insert(seq.id, seq.generated.clone());
        }
    }
    RestartRun {
        report,
        tokens,
        metrics: router.sched.engine.metrics.clone(),
        violations: router.sched.engine.invariant_violations(),
        fingerprints: router
            .supervisor
            .as_ref()
            .map(|s| s.checkpoint_fingerprints().to_vec())
            .unwrap_or_default(),
        kv_free_tokens: router.sched.kv.free_token_capacity(),
        kv_total_tokens: router.sched.kv.total_token_capacity(),
        refcount_violations: router.sched.kv.refcount_violations(),
    }
}

/// The capstone: under a seeded fatal plan the supervised run restarts,
/// replays, completes everything, and is bit-exact against its
/// fault-free twin — tokens AND the checkpoint fingerprint sequence.
#[test]
fn fatal_plan_run_recovers_and_is_bit_exact() {
    let trace = mixed_chat_doc_trace(10, 3, 0.002, 0.0005);
    let baseline = run(None, Some(sup_cfg()), true, &trace);
    assert_eq!(baseline.report.n_requests, trace.len(),
               "fault-free baseline must serve the whole trace");
    assert_eq!(baseline.metrics.faults_injected, 0);
    assert_eq!(baseline.report.recovery.engine_restarts, 0);
    assert!(baseline.report.recovery.checkpoint_rounds > 0,
            "supervised baseline never checkpointed");
    assert!(baseline.report.recovery.checkpoint_bytes > 0);

    let plan = FaultPlan {
        seed: restart_seed(),
        fatal: 0.02,
        max_burst: 2,
        ..FaultPlan::empty()
    };
    let faulted = run(Some(plan), Some(sup_cfg()), true, &trace);

    // the schedule fired, and every Fatal became a warm restart inside
    // the budget — zero run-ending escalations, nobody lost
    assert!(faulted.metrics.faults_injected > 0, "plan injected nothing");
    assert!(faulted.report.recovery.engine_restarts > 0,
            "no Fatal ever reached the supervisor");
    assert_eq!(faulted.report.recovery.escalations, 0);
    assert_eq!(faulted.report.n_requests, trace.len(),
               "all requests complete under the fatal plan");
    assert_eq!(faulted.report.failed, 0);
    assert_eq!(faulted.report.rejected, 0);
    assert_eq!(faulted.report.shed_requests, 0);

    // recovery re-uploaded from host mirrors only — never a download
    assert_eq!(faulted.metrics.sync_download_bytes, 0);

    // the auditor cross-checked rounds after every restore, stayed green
    assert!(faulted.violations.is_empty(), "{:?}", faulted.violations);
    assert!(faulted.refcount_violations.is_empty(),
            "{:?}", faulted.refcount_violations);
    if cfg!(any(debug_assertions, feature = "audit")) {
        assert!(faulted.metrics.audit_checks > 0,
                "auditor compiled out of the restart run");
    }

    // bit-exactness, twice over: every completed sequence decodes the
    // fault-free tokens, and the state fingerprint at every matched
    // logical checkpoint round is equal
    assert_eq!(faulted.tokens, baseline.tokens,
               "replayed outputs diverged from the fault-free twin");
    assert_eq!(faulted.fingerprints, baseline.fingerprints,
               "checkpoint fingerprints diverged at matched rounds");
}

/// A wedged execute never errors — it stalls. The per-step watchdog
/// converts the stall into a restart, and replay still decodes the
/// fault-free tokens.
#[test]
fn watchdog_restarts_wedged_steps_bit_exactly() {
    let trace = mixed_chat_doc_trace(6, 2, 0.002, 0.0005);
    let baseline = run(None, Some(sup_cfg()), true, &trace);
    assert_eq!(baseline.report.n_requests, trace.len());

    let plan = FaultPlan {
        seed: restart_seed(),
        wedge: 0.03,
        wedge_us: 300_000,
        max_burst: 1,
        ..FaultPlan::empty()
    };
    let scfg = SupervisorConfig {
        watchdog_step_s: Some(0.1),
        max_restarts: 16,
        ..sup_cfg()
    };
    let wedged = run(Some(plan), Some(scfg), true, &trace);

    assert!(wedged.metrics.faults_injected > 0, "plan injected nothing");
    assert!(wedged.report.recovery.watchdog_trips > 0,
            "no wedge ever tripped the watchdog");
    assert!(wedged.report.recovery.engine_restarts > 0);
    assert_eq!(wedged.report.recovery.escalations, 0);
    assert_eq!(wedged.report.n_requests, trace.len(),
               "all requests complete despite wedged steps");
    assert_eq!(wedged.report.failed, 0);
    assert_eq!(wedged.metrics.sync_download_bytes, 0);
    assert!(wedged.violations.is_empty(), "{:?}", wedged.violations);
    assert_eq!(wedged.tokens, baseline.tokens,
               "watchdog-discarded rounds did not replay bit-exactly");
}

/// Satellite 1: fatals landing in the admit-blocks-then-fail window of a
/// chunked prefill must not leak reservations — after the supervised run
/// drains, the block pool is EMPTY again and refcounts are clean.
/// Prefix sharing is off so no sealed prefix legitimately pins blocks.
#[test]
fn mid_prefill_fatals_leak_no_kv_reservations() {
    let trace = mixed_chat_doc_trace(4, 4, 0.002, 0.0005);
    let plan = FaultPlan {
        seed: restart_seed(),
        fatal: 0.05,
        max_burst: 2,
        ..FaultPlan::empty()
    };
    let out = run(Some(plan), Some(sup_cfg()), false, &trace);

    assert!(out.metrics.faults_injected > 0, "plan injected nothing");
    assert!(out.report.recovery.engine_restarts > 0,
            "no fatal ever interrupted the run");
    assert_eq!(out.report.n_requests, trace.len());
    assert_eq!(out.report.failed, 0);
    assert_eq!(out.kv_free_tokens, out.kv_total_tokens,
               "a mid-prefill fatal leaked KV reservations");
    assert!(out.refcount_violations.is_empty(),
            "{:?}", out.refcount_violations);
    assert!(out.violations.is_empty(), "{:?}", out.violations);
}

/// A spent restart budget is an OUTCOME, not a crash: the router drains
/// (waiting sheds, admitted work fails visibly) and the run returns a
/// report with the escalation counted.
#[test]
fn budget_exhaustion_drains_and_reports_instead_of_crashing() {
    let trace = mixed_chat_doc_trace(4, 1, 0.002, 0.0005);
    // every op fatals, no burst clamp: the supervisor restarts twice,
    // then the third failure exhausts the budget and escalates
    let plan = FaultPlan {
        seed: restart_seed(),
        fatal: 1.0,
        max_burst: 1_000_000,
        ..FaultPlan::empty()
    };
    let scfg = SupervisorConfig { max_restarts: 2, ..sup_cfg() };
    let out = run(Some(plan), Some(scfg), true, &trace);

    assert_eq!(out.report.recovery.engine_restarts, 2,
               "budget allows exactly two consecutive restarts");
    assert!(out.report.recovery.escalations >= 1,
            "exhaustion must be counted as an escalation");
    assert_eq!(out.report.n_requests, 0,
               "nothing completes when every op fatals");
    // every request is accounted for in a visible bucket
    assert_eq!(
        out.report.n_requests + out.report.failed
            + out.report.shed_requests + out.report.rejected,
        trace.len(),
        "drain must not lose or duplicate requests"
    );
    assert!(out.report.shed_requests + out.report.failed > 0);
    assert!(out.refcount_violations.is_empty(),
            "{:?}", out.refcount_violations);
}

/// Checkpoint/restore round-trip, directly: restoring a checkpoint into
/// a FRESH engine reproduces the exact state fingerprint, and replaying
/// from it converges to the same tokens as a run that never restarted.
#[test]
fn restore_into_fresh_engine_reproduces_the_fingerprint() {
    let rt = Runtime::new().expect("run `make artifacts` first");
    let cfg = "servethin";
    let c = rt.manifest().config(cfg).unwrap().clone();
    let mk_engine = || {
        let params = ParamStore::init(&c, 42);
        Engine::new(&rt, cfg, params, false, Sampler::Greedy, 0).unwrap()
    };
    let mk_kv = || {
        KvCacheManager::new(KvCacheConfig {
            n_layers: c.n_layers,
            k_dims: c.k_cache_dims,
            v_dims: c.v_cache_dims,
            block_tokens: 16,
            bytes_per_el_k: 2.0,
            bytes_per_el_v: 2.0,
            budget_bytes: 4e6,
        })
    };
    let chunk = rt.manifest().chunks_for(cfg).first().copied();
    let scfg = SchedConfig {
        max_batch: 6,
        round_budget: 64,
        chunk_tokens: chunk,
        retry_backoff_us: 20,
        ..SchedConfig::default()
    };
    let mut sched = Scheduler::with_config(mk_engine(), mk_kv(), scfg);
    let mut twin = Scheduler::with_config(mk_engine(), mk_kv(), scfg);
    let mut rng = Rng::new(restart_seed());
    for _ in 0..6 {
        let p = synth_prompt(12 + rng.below(24), c.vocab, &mut rng);
        sched.submit(p.clone(), 8, None);
        twin.submit(p, 8, None);
    }
    for _ in 0..3 {
        sched.step().unwrap();
        twin.step().unwrap();
    }
    let ck = sched.checkpoint();
    let fp = sched.engine.state_fingerprint();
    assert!(ck.host_bytes() > 0, "checkpoint pinned no host bytes");

    // perturb well past the checkpoint, then restore into a FRESH engine
    for _ in 0..5 {
        sched.step().unwrap();
    }
    assert_ne!(sched.engine.state_fingerprint(), fp,
               "perturbation rounds changed nothing — test is vacuous");
    sched.restore_from(mk_engine(), &ck).unwrap();
    assert_eq!(sched.engine.state_fingerprint(), fp,
               "restore did not reproduce the checkpoint fingerprint");
    assert_eq!(sched.engine.metrics.sync_download_bytes, 0,
               "restore must rebuild device state from host mirrors");

    // replay from the checkpoint converges to the never-restarted twin
    sched.run_to_completion().unwrap();
    twin.run_to_completion().unwrap();
    let toks = |s: &Scheduler| -> Vec<(u64, Vec<i32>)> {
        let mut v: Vec<(u64, Vec<i32>)> = s
            .finished
            .iter()
            .map(|q| (q.id, q.generated.clone()))
            .collect();
        v.sort();
        v
    };
    assert_eq!(toks(&sched), toks(&twin),
               "replay from the restored checkpoint diverged");
    assert!(sched.engine.invariant_violations().is_empty());
}
