//! Chaos e2e: the mixed chat+doc churn trace under seeded fault
//! schedules (ISSUE 7 capstone).
//!
//! The contracts under test:
//! - the serving loop SURVIVES a seeded FaultPlan covering all four fault
//!   kinds with zero Fatal escalations (burst-clamped injector + retry
//!   budget > max_burst guarantees recovery),
//! - sequences untouched by quarantine decode BIT-EXACTLY the tokens of
//!   the fault-free run (per-lane attention: a lane's greedy outputs
//!   depend only on its own prompt and cache, and the injector draws from
//!   its own RNG stream — never the engine's),
//! - the delta-synced host mirror needs NO full-arena downloads to
//!   recover (`sync_download_bytes == 0` throughout),
//! - the runtime auditor stays green across every rollback,
//! - an EMPTY plan is byte-identical to a run with no injector at all.
//!
//! `CHAOS_SEED` selects the fault schedule (CI runs two fixed seeds).

use std::collections::BTreeMap;

use thinkeys::coordinator::engine::Engine;
use thinkeys::coordinator::kvcache::{KvCacheConfig, KvCacheManager};
use thinkeys::coordinator::metrics::{EngineMetrics, ServeReport};
use thinkeys::coordinator::router::{
    bucket_of, ReportBucket, Router, RouterPolicy,
};
use thinkeys::coordinator::sampling::Sampler;
use thinkeys::coordinator::scheduler::{SchedConfig, Scheduler};
use thinkeys::coordinator::sequence::{Priority, Sequence};
use thinkeys::datagen::arrival::{mixed_chat_doc_trace, RequestSpec};
use thinkeys::runtime::{FaultPlan, ParamStore, Runtime};

fn chaos_seed() -> u64 {
    std::env::var("CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(7)
}

/// Everything a chaos run leaves behind once the runtime is gone.
struct ChaosRun {
    report: ServeReport,
    /// id -> generated tokens, COMPLETED sequences only. Submission order
    /// in `run_trace` is trace order, so ids line up across runs of the
    /// same trace.
    tokens: BTreeMap<u64, Vec<i32>>,
    finished: Vec<Sequence>,
    metrics: EngineMetrics,
    violations: Vec<String>,
}

fn run(
    plan: Option<FaultPlan>,
    policy: RouterPolicy,
    budget_mb: f64,
    max_step_retries: usize,
    trace: &[RequestSpec],
) -> ChaosRun {
    let rt = Runtime::new().expect("run `make artifacts` first");
    if let Some(p) = plan {
        rt.install_fault_plan(p);
    }
    let cfg = "servethin";
    let c = rt.manifest().config(cfg).unwrap().clone();
    let params = ParamStore::init(&c, 42);
    let eng = Engine::new(&rt, cfg, params, false, Sampler::Greedy, 0).unwrap();
    let kv = KvCacheManager::new(KvCacheConfig {
        n_layers: c.n_layers,
        k_dims: c.k_cache_dims,
        v_dims: c.v_cache_dims,
        block_tokens: 16,
        bytes_per_el_k: 2.0,
        bytes_per_el_v: 2.0,
        budget_bytes: budget_mb * 1e6,
    });
    let chunk = rt.manifest().chunks_for(cfg).first().copied();
    let sched = Scheduler::with_config(eng, kv, SchedConfig {
        max_batch: 8,
        round_budget: 64,
        chunk_tokens: chunk,
        interactive_weight: 4,
        max_step_retries,
        retry_backoff_us: 50,
        ..SchedConfig::default()
    });
    let mut router = Router::new(sched).with_policy(policy);
    let report = router
        .run_trace(trace, 0)
        .expect("the chaos serving loop must survive (zero Fatal)");
    let mut tokens = BTreeMap::new();
    for seq in &router.sched.finished {
        if bucket_of(seq) == ReportBucket::Completed {
            tokens.insert(seq.id, seq.generated.clone());
        }
    }
    ChaosRun {
        report,
        tokens,
        finished: router.sched.finished.clone(),
        metrics: router.sched.engine.metrics.clone(),
        violations: router.sched.engine.invariant_violations(),
    }
}

fn chaos_plan(seed: u64) -> FaultPlan {
    // all four fault kinds enabled (the acceptance bar is >= 3)
    FaultPlan {
        seed,
        exec: 0.05,
        load: 0.03,
        corrupt: 0.03,
        latency: 0.08,
        latency_us: 200,
        max_burst: 2,
        ..FaultPlan::empty()
    }
}

/// The capstone: survival, recovery, bit-exactness, green audits, and a
/// cold host-mirror download counter, all under one seeded schedule.
#[test]
fn chaos_mixed_trace_survives_and_recovers() {
    let trace = mixed_chat_doc_trace(12, 4, 0.002, 0.0005);
    let inert = RouterPolicy::default();
    let baseline = run(None, inert, 4.0, 4, &trace);
    assert_eq!(baseline.report.n_requests, trace.len(),
               "fault-free baseline must serve the whole trace");
    assert_eq!(baseline.metrics.faults_injected, 0);

    let plan = chaos_plan(chaos_seed());
    let faulted = run(Some(plan), inert, 4.0, 4, &trace);

    // survival: the retry budget (4) exceeds max_burst (2), so every
    // retryable fault recovers — nothing escalates, nobody is lost
    assert_eq!(faulted.metrics.fatal_steps, 0, "zero Fatal escalations");
    assert_eq!(faulted.report.n_requests, trace.len(),
               "all requests complete under the bounded fault schedule");
    assert_eq!(faulted.report.failed, 0);

    // the schedule actually fired, and recovery actually happened
    assert!(faulted.metrics.faults_injected > 0, "plan injected nothing");
    assert!(faulted.metrics.step_retries > 0, "no step ever retried");
    assert!(faulted.metrics.recovered_steps > 0, "no step ever recovered");
    assert!(faulted.metrics.retry_backoff.count() > 0);

    // recovery never resorted to full-arena downloads: the host mirror +
    // rollback are enough to rebuild device state
    assert_eq!(faulted.metrics.sync_download_bytes, 0);

    // the runtime auditor cross-checked every round and stayed green
    assert!(faulted.violations.is_empty(), "{:?}", faulted.violations);
    if cfg!(any(debug_assertions, feature = "audit")) {
        assert!(faulted.metrics.audit_checks > 0,
                "auditor compiled out of the chaos run");
    }

    // bit-exactness: every completed sequence decodes exactly the
    // fault-free tokens (rolled-back steps consume no sampler RNG)
    for (id, toks) in &faulted.tokens {
        assert_eq!(Some(toks), baseline.tokens.get(id).as_deref(),
                   "seq {id} diverged from the fault-free run");
    }
}

/// An empty plan must be indistinguishable from no injector at all —
/// same tokens, same counters, nothing injected, nothing retried.
#[test]
fn empty_fault_plan_is_byte_identical() {
    let trace = mixed_chat_doc_trace(8, 2, 0.002, 0.0005);
    let inert = RouterPolicy::default();
    let baseline = run(None, inert, 4.0, 4, &trace);
    let empty = run(Some(FaultPlan::empty()), inert, 4.0, 4, &trace);

    assert_eq!(empty.metrics.faults_injected, 0);
    assert_eq!(empty.metrics.step_retries, 0);
    assert_eq!(empty.metrics.recovered_steps, 0);
    assert_eq!(empty.metrics.quarantined_seqs, 0);
    assert_eq!(empty.tokens, baseline.tokens,
               "empty plan changed decoded tokens");
    assert_eq!(empty.report.n_requests, baseline.report.n_requests);
    assert_eq!(empty.report.rejected, baseline.report.rejected);
    assert_eq!(empty.report.failed, 0);
    assert_eq!(empty.report.shed_requests, 0);
}

/// Degradation policy: under sustained faults + KV pressure, Batch work
/// sheds at its deadline while every Interactive request completes —
/// Batch first, chat alive.
#[test]
fn degraded_router_sheds_batch_first_keeps_interactive_alive() {
    // capacity 192 tokens: one 128-token doc reservation at a time, so
    // five of the six docs queue behind the first and age past the
    // deadline while latency spikes keep the run degraded
    let trace = mixed_chat_doc_trace(12, 6, 0.002, 0.0005);
    let policy = RouterPolicy {
        batch_deadline_s: Some(0.001),
        interactive_deadline_s: None,
        only_when_degraded: true,
    };
    let plan = FaultPlan {
        seed: chaos_seed(),
        exec: 0.05,
        latency: 0.6,
        latency_us: 1000,
        ..FaultPlan::empty()
    };
    let out = run(Some(plan), policy, 0.0922, 4, &trace);

    assert!(out.metrics.faults_injected > 0);
    assert_eq!(out.metrics.fatal_steps, 0);
    assert!(out.report.shed_requests > 0, "no batch doc was ever shed");
    let interactive_done = out
        .finished
        .iter()
        .filter(|s| s.priority == Priority::Interactive)
        .filter(|s| bucket_of(s) == ReportBucket::Completed)
        .count();
    assert_eq!(interactive_done, 12,
               "interactive traffic must survive degradation untouched");
    // with no interactive deadline, nothing interactive is ever shed
    assert!(out
        .finished
        .iter()
        .filter(|s| s.priority == Priority::Interactive)
        .all(|s| bucket_of(s) != ReportBucket::Shed));
}

/// Quarantine: with the retry budget at zero, a corrupt-output fault
/// evicts ONLY the implicated sequence; the rest of the batch keeps
/// decoding and still matches the fault-free run bit-exactly.
#[test]
fn quarantine_evicts_only_the_implicated_sequence() {
    let trace = mixed_chat_doc_trace(8, 2, 0.002, 0.0005);
    let inert = RouterPolicy::default();
    let baseline = run(None, inert, 4.0, 4, &trace);
    // corrupt-only plan: every fired fault is sequence-local, so a zero
    // retry budget quarantines deterministically and can never escalate
    let plan = FaultPlan {
        seed: chaos_seed(),
        corrupt: 0.08,
        ..FaultPlan::empty()
    };
    let out = run(Some(plan), inert, 4.0, 0, &trace);

    assert_eq!(out.metrics.fatal_steps, 0);
    assert!(out.report.failed > 0, "no sequence was ever quarantined");
    assert_eq!(out.metrics.quarantined_seqs as usize, out.report.failed);
    assert_eq!(out.report.n_requests + out.report.failed, trace.len(),
               "quarantine must not lose or duplicate requests");
    assert!(out.violations.is_empty(), "{:?}", out.violations);
    assert_eq!(out.metrics.sync_download_bytes, 0);
    // survivors are bit-exact: eviction freed the lane, the regroup kept
    // every other lane's cache rows intact
    for (id, toks) in &out.tokens {
        assert_eq!(Some(toks), baseline.tokens.get(id).as_deref(),
                   "surviving seq {id} diverged after a quarantine");
    }
}
