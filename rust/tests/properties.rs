//! Property-based tests over the substrates and coordinator invariants,
//! via the in-tree mini proptest framework.

use thinkeys::coordinator::kvcache::{KvCacheConfig, KvCacheManager};
use thinkeys::datagen::{copyback, gsm_mini, kvretrieval};
use thinkeys::proptest::{check_close, property, small_size};
use thinkeys::substrate::linalg::{low_rank_approx, svd_any};
use thinkeys::substrate::mathutil::{logsumexp, softmax};
use thinkeys::substrate::rng::Rng;
use thinkeys::substrate::tensor::{dequantize_rows_q8, quantize_rows_q8,
                                  KvQuant, RowArena, Tensor, Q8_SCALE_EPS};
use thinkeys::substrate::json::Value;

#[test]
fn prop_svd_reconstructs_any_shape() {
    property("svd reconstruction", 40, |rng| {
        let m = small_size(rng, 24);
        let n = small_size(rng, 24);
        let a = Tensor::randn(&[m, n], 1.0, rng);
        let d = svd_any(&a);
        let k = d.s.len();
        let mut us = d.u.clone();
        for row in 0..us.shape[0] {
            for j in 0..k {
                us.data[row * k + j] *= d.s[j];
            }
        }
        let r = us.matmul(&d.v.t());
        check_close(&a.data, &r.data, 1e-3, 1e-3)
    });
}

#[test]
fn prop_low_rank_error_bounded_by_tail() {
    property("eckart-young bound", 25, |rng| {
        let m = 4 + small_size(rng, 12);
        let n = 2 + small_size(rng, 6).min(m - 1);
        let a = Tensor::randn(&[m, n], 1.0, rng);
        let d = svd_any(&a);
        let r = 1 + rng.below(n.min(d.s.len()));
        let ar = low_rank_approx(&a, r);
        let mut diff = a.clone();
        for (x, y) in diff.data.iter_mut().zip(&ar.data) {
            *x -= y;
        }
        let err = diff.frobenius();
        let tail: f64 = d.s[r.min(d.s.len())..]
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt();
        if err <= tail + 1e-2 {
            Ok(())
        } else {
            Err(format!("err {err} > tail {tail} (rank {r}, {m}x{n})"))
        }
    });
}

#[test]
fn prop_softmax_is_distribution() {
    property("softmax sums to 1", 50, |rng| {
        let n = small_size(rng, 200);
        let mut xs: Vec<f32> =
            (0..n).map(|_| (rng.normal() * 20.0) as f32).collect();
        softmax(&mut xs);
        let s: f32 = xs.iter().sum();
        if (s - 1.0).abs() < 1e-4 && xs.iter().all(|x| *x >= 0.0) {
            Ok(())
        } else {
            Err(format!("sum {s}"))
        }
    });
}

#[test]
fn prop_logsumexp_bounds() {
    property("max <= lse <= max + ln n", 50, |rng| {
        let n = small_size(rng, 100);
        let xs: Vec<f32> =
            (0..n).map(|_| (rng.normal() * 50.0) as f32).collect();
        let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let l = logsumexp(&xs);
        if l >= m - 1e-4 && l <= m + (n as f32).ln() + 1e-4 {
            Ok(())
        } else {
            Err(format!("lse {l} max {m} n {n}"))
        }
    });
}

#[test]
fn prop_kvcache_accounting_balances() {
    property("kv alloc/free balances", 30, |rng| {
        let mut m = KvCacheManager::new(KvCacheConfig {
            n_layers: 2 + rng.below(4),
            k_dims: 8 << rng.below(4),
            v_dims: 64,
            block_tokens: 8 << rng.below(3),
            bytes_per_el_k: 2.0,
            bytes_per_el_v: 2.0,
            budget_bytes: 2e6,
        });
        let cap0 = m.free_token_capacity();
        let mut live: Vec<u64> = Vec::new();
        for i in 0..40u64 {
            match rng.below(3) {
                0 => {
                    let want = 1 + rng.below(64);
                    if m.can_admit(want) {
                        m.allocate(i + 1, want).map_err(|e| e.to_string())?;
                        live.push(i + 1);
                    }
                }
                1 => {
                    if let Some(&id) =
                        live.get(rng.below(live.len().max(1)).min(
                            live.len().saturating_sub(1)))
                    {
                        if !live.is_empty() {
                            let _ = m.extend(id, 1 + rng.below(8));
                        }
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let id = live.swap_remove(rng.below(live.len()));
                        m.release(id);
                    }
                }
            }
        }
        for id in live {
            m.release(id);
        }
        if m.free_token_capacity() == cap0 && m.stats().tokens == 0 {
            Ok(())
        } else {
            Err(format!("leak: {} vs {}", m.free_token_capacity(), cap0))
        }
    });
}

#[test]
fn prop_quantize_roundtrip_error_bounded() {
    // ISSUE 4 satellite: per-row scale correctness + worst-case error
    // <= scale/2 per element, across random row widths/counts/magnitudes
    property("q8 round-trip error <= scale/2", 60, |rng| {
        let d = small_size(rng, 96);
        let rows = small_size(rng, 12);
        let mag = 10f32.powi(rng.below(7) as i32 - 3); // 1e-3 .. 1e3
        let t = Tensor::randn(&[rows, d], mag, rng);
        let (q, s) = quantize_rows_q8(&t.data, d);
        if s.len() != rows {
            return Err(format!("{} scales for {rows} rows", s.len()));
        }
        for (r, row) in t.data.chunks(d).enumerate() {
            let amax = row.iter().fold(0f32, |m, &x| m.max(x.abs()));
            let want = (amax / 127.0).max(Q8_SCALE_EPS);
            if (s[r] - want).abs() > want * 1e-6 {
                return Err(format!("row {r} scale {} want {want}", s[r]));
            }
        }
        let back = dequantize_rows_q8(&q, &s, d);
        for (i, (&x, &y)) in t.data.iter().zip(&back).enumerate() {
            let bound = s[i / d] * 0.5 + s[i / d] * 1e-5;
            if (x - y).abs() > bound {
                return Err(format!(
                    "elem {i}: |{x} - {y}| > scale/2 ({})", s[i / d]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quantize_zero_and_outlier_rows() {
    property("q8 zero/outlier row edge cases", 40, |rng| {
        let d = 1 + small_size(rng, 31);
        let rows = 3usize;
        let mut data = vec![0f32; rows * d];
        // row 0: all zero; row 1: one huge outlier among tiny values;
        // row 2: random
        for v in data[d..2 * d].iter_mut() {
            *v = (rng.normal() * 1e-3) as f32;
        }
        data[d + rng.below(d)] = 1e4;
        for v in data[2 * d..].iter_mut() {
            *v = rng.normal() as f32;
        }
        let (q, s) = quantize_rows_q8(&data, d);
        // zero row: exactly-zero codes, eps scale, exact-zero dequant
        if q[..d].iter().any(|&c| c != 0) || s[0] != Q8_SCALE_EPS {
            return Err("zero row not exact".into());
        }
        // outlier row: the outlier hits the top code, the rest collapse
        // toward zero but stay within scale/2
        if q[d..2 * d].iter().map(|&c| c.abs()).max() != Some(127) {
            return Err("outlier did not hit code 127".into());
        }
        let back = dequantize_rows_q8(&q, &s, d);
        for (i, (&x, &y)) in data.iter().zip(&back).enumerate() {
            if (x - y).abs() > s[i / d] * 0.5 + 1e-6 {
                return Err(format!("elem {i} outside scale/2"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_row_arena_copies_preserve_values() {
    // the engine's park/unpark/repack primitive: row-range copies through
    // RowArena must preserve values exactly (codes+scales move together)
    property("row arena copy preserves rows", 40, |rng| {
        let quant = if rng.below(2) == 0 { KvQuant::Fp32 } else { KvQuant::Q8 };
        let d = 1 + small_size(rng, 24);
        let rows = 2 + small_size(rng, 10);
        let t = Tensor::randn(&[rows, d], 1.0, rng);
        let mut a = RowArena::zeros(quant, d, rows);
        a.write_f32_rows(0, &t.data, rows);
        // copy a random row range through a second arena and back
        let start = rng.below(rows);
        let n = 1 + rng.below(rows - start);
        let mut b = RowArena::zeros(quant, d, n);
        b.copy_rows(0, &a, start, n);
        let mut c = RowArena::zeros(quant, d, rows);
        c.copy_rows(start, &b, 0, n);
        let (fa, fc) = (a.to_f32(), c.to_f32());
        check_close(&fa[start * d..(start + n) * d],
                    &fc[start * d..(start + n) * d], 0.0, 0.0)?;
        // payload accounting matches the dtype
        let expect = rows * d * quant.elem_bytes();
        if a.payload_bytes() != expect {
            return Err(format!("payload {} != {expect}", a.payload_bytes()));
        }
        Ok(())
    });
}

#[test]
fn prop_gsm_roundtrip_any_problem() {
    property("gsm encode/parse roundtrip", 60, |rng| {
        let p = gsm_mini::Problem::sample(rng);
        let seq = gsm_mini::encode_sequence(&p);
        let a_pos = seq.iter().position(|&t| t == gsm_mini::T_A).unwrap();
        match gsm_mini::parse_answer(&seq[a_pos..]) {
            Some(ans) if ans == p.answer() => Ok(()),
            other => Err(format!("{p:?} -> {other:?}")),
        }
    });
}

#[test]
fn prop_task_batches_respect_masks() {
    property("task masks select supervised positions", 30, |rng| {
        let b = copyback::batch(4, 32, rng);
        for i in 0..4 {
            for t in 0..32 {
                let masked = b.mask[i * 32 + t] == 1.0;
                if masked != (t >= copyback::OFFSET_K) {
                    return Err(format!("copyback mask wrong at {t}"));
                }
            }
        }
        let kb = kvretrieval::batch(4, 24, rng);
        let per_row: Vec<f32> = (0..4)
            .map(|i| kb.mask[i * 24..(i + 1) * 24].iter().sum())
            .collect();
        if per_row.iter().all(|&x| x == 1.0) {
            Ok(())
        } else {
            Err(format!("kvret mask {per_row:?}"))
        }
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    property("json roundtrip", 40, |rng| {
        fn gen(rng: &mut Rng, depth: usize) -> Value {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Value::Null,
                1 => Value::Bool(rng.below(2) == 0),
                2 => Value::Num((rng.normal() * 100.0).round()),
                3 => Value::Str(format!("s{}\n\"{}\"", rng.below(100),
                                        rng.below(10))),
                4 => Value::Arr((0..rng.below(4))
                    .map(|_| gen(rng, depth - 1))
                    .collect()),
                _ => Value::Obj((0..rng.below(4))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect()),
            }
        }
        let v = gen(rng, 3);
        let parsed =
            Value::parse(&v.to_string()).map_err(|e| e.to_string())?;
        if parsed == v {
            Ok(())
        } else {
            Err(format!("{v:?} != {parsed:?}"))
        }
    });
}
