//! Property-based tests over the substrates and coordinator invariants,
//! via the in-tree mini proptest framework.

use thinkeys::coordinator::kvcache::{KvCacheConfig, KvCacheManager};
use thinkeys::datagen::{copyback, gsm_mini, kvretrieval};
use thinkeys::proptest::{check_close, property, small_size};
use thinkeys::substrate::linalg::{low_rank_approx, svd_any};
use thinkeys::substrate::mathutil::{logsumexp, softmax};
use thinkeys::substrate::rng::Rng;
use thinkeys::substrate::tensor::{dequantize_rows_q8, quantize_rows_q8,
                                  KvQuant, RowArena, Tensor, Q8_SCALE_EPS};
use thinkeys::substrate::json::Value;

#[test]
fn prop_svd_reconstructs_any_shape() {
    property("svd reconstruction", 40, |rng| {
        let m = small_size(rng, 24);
        let n = small_size(rng, 24);
        let a = Tensor::randn(&[m, n], 1.0, rng);
        let d = svd_any(&a);
        let k = d.s.len();
        let mut us = d.u.clone();
        for row in 0..us.shape[0] {
            for j in 0..k {
                us.data[row * k + j] *= d.s[j];
            }
        }
        let r = us.matmul(&d.v.t());
        check_close(&a.data, &r.data, 1e-3, 1e-3)
    });
}

#[test]
fn prop_low_rank_error_bounded_by_tail() {
    property("eckart-young bound", 25, |rng| {
        let m = 4 + small_size(rng, 12);
        let n = 2 + small_size(rng, 6).min(m - 1);
        let a = Tensor::randn(&[m, n], 1.0, rng);
        let d = svd_any(&a);
        let r = 1 + rng.below(n.min(d.s.len()));
        let ar = low_rank_approx(&a, r);
        let mut diff = a.clone();
        for (x, y) in diff.data.iter_mut().zip(&ar.data) {
            *x -= y;
        }
        let err = diff.frobenius();
        let tail: f64 = d.s[r.min(d.s.len())..]
            .iter()
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt();
        if err <= tail + 1e-2 {
            Ok(())
        } else {
            Err(format!("err {err} > tail {tail} (rank {r}, {m}x{n})"))
        }
    });
}

#[test]
fn prop_softmax_is_distribution() {
    property("softmax sums to 1", 50, |rng| {
        let n = small_size(rng, 200);
        let mut xs: Vec<f32> =
            (0..n).map(|_| (rng.normal() * 20.0) as f32).collect();
        softmax(&mut xs);
        let s: f32 = xs.iter().sum();
        if (s - 1.0).abs() < 1e-4 && xs.iter().all(|x| *x >= 0.0) {
            Ok(())
        } else {
            Err(format!("sum {s}"))
        }
    });
}

#[test]
fn prop_logsumexp_bounds() {
    property("max <= lse <= max + ln n", 50, |rng| {
        let n = small_size(rng, 100);
        let xs: Vec<f32> =
            (0..n).map(|_| (rng.normal() * 50.0) as f32).collect();
        let m = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let l = logsumexp(&xs);
        if l >= m - 1e-4 && l <= m + (n as f32).ln() + 1e-4 {
            Ok(())
        } else {
            Err(format!("lse {l} max {m} n {n}"))
        }
    });
}

#[test]
fn prop_kvcache_accounting_balances() {
    property("kv alloc/free balances", 30, |rng| {
        let mut m = KvCacheManager::new(KvCacheConfig {
            n_layers: 2 + rng.below(4),
            k_dims: 8 << rng.below(4),
            v_dims: 64,
            block_tokens: 8 << rng.below(3),
            bytes_per_el_k: 2.0,
            bytes_per_el_v: 2.0,
            budget_bytes: 2e6,
        });
        let cap0 = m.free_token_capacity();
        let mut live: Vec<u64> = Vec::new();
        for i in 0..40u64 {
            match rng.below(3) {
                0 => {
                    let want = 1 + rng.below(64);
                    if m.can_admit(want) {
                        m.allocate(i + 1, want).map_err(|e| e.to_string())?;
                        live.push(i + 1);
                    }
                }
                1 => {
                    if let Some(&id) =
                        live.get(rng.below(live.len().max(1)).min(
                            live.len().saturating_sub(1)))
                    {
                        if !live.is_empty() {
                            let _ = m.extend(id, 1 + rng.below(8));
                        }
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let id = live.swap_remove(rng.below(live.len()));
                        m.release(id);
                    }
                }
            }
        }
        for id in live {
            m.release(id);
        }
        if m.free_token_capacity() == cap0 && m.stats().tokens == 0 {
            Ok(())
        } else {
            Err(format!("leak: {} vs {}", m.free_token_capacity(), cap0))
        }
    });
}

#[test]
fn prop_quantize_roundtrip_error_bounded() {
    // ISSUE 4 satellite: per-row scale correctness + worst-case error
    // <= scale/2 per element, across random row widths/counts/magnitudes
    property("q8 round-trip error <= scale/2", 60, |rng| {
        let d = small_size(rng, 96);
        let rows = small_size(rng, 12);
        let mag = 10f32.powi(rng.below(7) as i32 - 3); // 1e-3 .. 1e3
        let t = Tensor::randn(&[rows, d], mag, rng);
        let (q, s) = quantize_rows_q8(&t.data, d);
        if s.len() != rows {
            return Err(format!("{} scales for {rows} rows", s.len()));
        }
        for (r, row) in t.data.chunks(d).enumerate() {
            let amax = row.iter().fold(0f32, |m, &x| m.max(x.abs()));
            let want = (amax / 127.0).max(Q8_SCALE_EPS);
            if (s[r] - want).abs() > want * 1e-6 {
                return Err(format!("row {r} scale {} want {want}", s[r]));
            }
        }
        let back = dequantize_rows_q8(&q, &s, d);
        for (i, (&x, &y)) in t.data.iter().zip(&back).enumerate() {
            let bound = s[i / d] * 0.5 + s[i / d] * 1e-5;
            if (x - y).abs() > bound {
                return Err(format!(
                    "elem {i}: |{x} - {y}| > scale/2 ({})", s[i / d]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quantize_zero_and_outlier_rows() {
    property("q8 zero/outlier row edge cases", 40, |rng| {
        let d = 1 + small_size(rng, 31);
        let rows = 3usize;
        let mut data = vec![0f32; rows * d];
        // row 0: all zero; row 1: one huge outlier among tiny values;
        // row 2: random
        for v in data[d..2 * d].iter_mut() {
            *v = (rng.normal() * 1e-3) as f32;
        }
        data[d + rng.below(d)] = 1e4;
        for v in data[2 * d..].iter_mut() {
            *v = rng.normal() as f32;
        }
        let (q, s) = quantize_rows_q8(&data, d);
        // zero row: exactly-zero codes, eps scale, exact-zero dequant
        if q[..d].iter().any(|&c| c != 0) || s[0] != Q8_SCALE_EPS {
            return Err("zero row not exact".into());
        }
        // outlier row: the outlier hits the top code, the rest collapse
        // toward zero but stay within scale/2
        if q[d..2 * d].iter().map(|&c| c.abs()).max() != Some(127) {
            return Err("outlier did not hit code 127".into());
        }
        let back = dequantize_rows_q8(&q, &s, d);
        for (i, (&x, &y)) in data.iter().zip(&back).enumerate() {
            if (x - y).abs() > s[i / d] * 0.5 + 1e-6 {
                return Err(format!("elem {i} outside scale/2"));
            }
        }
        Ok(())
    });
}

/// Minimal f32 decode attention over a dense cache arena, with KV-head
/// grouping expressed exactly as the serving kernels express it (the
/// Pallas index map `kv head = q head / group`, ISSUE 5): query head `qh`
/// reads kv head `qh / group`. q: (H, dqk) row-major; k: (Hkv, N, dqk);
/// v: (Hkv, N, dv); positions 0..=pos are live. Returns (H, dv).
#[allow(clippy::too_many_arguments)]
fn grouped_attention_decode(q: &[f32], k: &[f32], v: &[f32], h: usize,
                            hkv: usize, n: usize, dqk: usize, dv: usize,
                            pos: usize) -> Vec<f32> {
    let group = h / hkv;
    let scale = 1.0 / (dqk as f32).sqrt();
    let mut out = vec![0f32; h * dv];
    for qh in 0..h {
        let kh = qh / group;
        let mut scores = vec![0f32; pos + 1];
        for (j, s) in scores.iter_mut().enumerate() {
            let mut acc = 0f32;
            for t in 0..dqk {
                acc += q[qh * dqk + t] * k[(kh * n + j) * dqk + t];
            }
            *s = acc * scale;
        }
        let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut w: Vec<f32> = scores.iter().map(|s| (s - m).exp()).collect();
        let den: f32 = w.iter().sum();
        for wj in w.iter_mut() {
            *wj /= den;
        }
        for (j, wj) in w.iter().enumerate() {
            for t in 0..dv {
                out[qh * dv + t] += wj * v[(kh * n + j) * dv + t];
            }
        }
    }
    out
}

/// Duplicate each kv head `group` times: (Hkv, N, d) -> (Hkv*group, N, d)
/// — the MHA reference the grouped path must reproduce.
fn repeat_kv(x: &[f32], hkv: usize, n: usize, d: usize, group: usize)
    -> Vec<f32> {
    let mut out = Vec::with_capacity(hkv * group * n * d);
    for kh in 0..hkv {
        for _ in 0..group {
            out.extend_from_slice(&x[kh * n * d..(kh + 1) * n * d]);
        }
    }
    out
}

#[test]
fn prop_grouped_decode_bit_matches_duplicated_mha() {
    // ISSUE 5 satellite: GQA attention with group size g must BIT-match
    // an MHA reference whose KV cache duplicates each kv head g times —
    // group broadcast is pure indexing, never arithmetic. Random head
    // counts, group sizes, tier-like arena lengths, and asymmetric dims.
    property("grouped decode == duplicated-kv MHA (bitwise)", 40, |rng| {
        let hkv = 1 + rng.below(3);
        let group = [1usize, 2, 4][rng.below(3)];
        let h = hkv * group;
        let n = [8usize, 16, 32, 64][rng.below(4)]; // tier-like lengths
        let dqk = 1 + rng.below(8);
        let dv = 1 + rng.below(16);
        let pos = rng.below(n);
        let q = Tensor::randn(&[h, dqk], 1.0, rng);
        let k = Tensor::randn(&[hkv, n, dqk], 1.0, rng);
        let v = Tensor::randn(&[hkv, n, dv], 1.0, rng);
        let grouped = grouped_attention_decode(
            &q.data, &k.data, &v.data, h, hkv, n, dqk, dv, pos);
        let kd = repeat_kv(&k.data, hkv, n, dqk, group);
        let vd = repeat_kv(&v.data, hkv, n, dv, group);
        let mha = grouped_attention_decode(
            &q.data, &kd, &vd, h, h, n, dqk, dv, pos);
        if grouped == mha {
            Ok(())
        } else {
            Err(format!(
                "grouped != duplicated MHA at h{h}/hkv{hkv} n{n} \
                 dqk{dqk} dv{dv} pos{pos}"
            ))
        }
    });
}

#[test]
fn prop_grouped_q8_decode_bounded_vs_fp32() {
    // The q8 half of the grouped-parity contract: quantizing the grouped
    // cache per ROW (one scale across the flat Hkv·d row, the serving
    // arena layout) and attending over the dequantized rows must stay
    // boundedly close to the fp32 grouped reference — and remain
    // BIT-identical to the duplicated-kv MHA run over the same
    // dequantized rows (grouping commutes with quantization).
    property("grouped q8 decode bounded + bit-stable", 30, |rng| {
        let hkv = 1 + rng.below(2);
        let group = [2usize, 4][rng.below(2)];
        let h = hkv * group;
        let n = [8usize, 16, 32][rng.below(3)];
        let dqk = 1 + rng.below(6);
        let dv = 1 + rng.below(12);
        let pos = rng.below(n);
        let q = Tensor::randn(&[h, dqk], 1.0, rng);
        // cache rows in arena layout: (N, Hkv*d) with ONE scale per row
        let k_rows = Tensor::randn(&[n, hkv * dqk], 1.0, rng);
        let v_rows = Tensor::randn(&[n, hkv * dv], 1.0, rng);
        let (kq, ks) = quantize_rows_q8(&k_rows.data, hkv * dqk);
        let (vq, vs) = quantize_rows_q8(&v_rows.data, hkv * dv);
        let kdq = dequantize_rows_q8(&kq, &ks, hkv * dqk);
        let vdq = dequantize_rows_q8(&vq, &vs, hkv * dv);
        // arena layout (N, Hkv*d) -> head-major (Hkv, N, d)
        let to_heads = |rows: &[f32], d: usize| -> Vec<f32> {
            let mut out = vec![0f32; hkv * n * d];
            for j in 0..n {
                for kh in 0..hkv {
                    for t in 0..d {
                        out[(kh * n + j) * d + t] =
                            rows[j * hkv * d + kh * d + t];
                    }
                }
            }
            out
        };
        let (k32, v32) = (to_heads(&k_rows.data, dqk),
                          to_heads(&v_rows.data, dv));
        let (k8, v8) = (to_heads(&kdq, dqk), to_heads(&vdq, dv));
        let fp32 = grouped_attention_decode(
            &q.data, &k32, &v32, h, hkv, n, dqk, dv, pos);
        let deq = grouped_attention_decode(
            &q.data, &k8, &v8, h, hkv, n, dqk, dv, pos);
        let err = fp32
            .iter()
            .zip(&deq)
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max);
        // per-element quantization error is <= scale/2 (~0.016 at unit
        // magnitudes); the softmax mixing keeps the output perturbation
        // the same order — 0.15 is a loose but meaningful ceiling
        if !(err.is_finite() && err < 0.15) {
            return Err(format!("q8 grouped decode error {err}"));
        }
        let kd = repeat_kv(&k8, hkv, n, dqk, group);
        let vd = repeat_kv(&v8, hkv, n, dv, group);
        let mha = grouped_attention_decode(
            &q.data, &kd, &vd, h, h, n, dqk, dv, pos);
        if deq != mha {
            return Err("grouping does not commute with dequant".into());
        }
        Ok(())
    });
}

#[test]
fn prop_row_arena_copies_preserve_values() {
    // the engine's park/unpark/repack primitive: row-range copies through
    // RowArena must preserve values exactly (codes+scales move together)
    property("row arena copy preserves rows", 40, |rng| {
        let quant = if rng.below(2) == 0 { KvQuant::Fp32 } else { KvQuant::Q8 };
        let d = 1 + small_size(rng, 24);
        let rows = 2 + small_size(rng, 10);
        let t = Tensor::randn(&[rows, d], 1.0, rng);
        let mut a = RowArena::zeros(quant, d, rows);
        a.write_f32_rows(0, &t.data, rows);
        // copy a random row range through a second arena and back
        let start = rng.below(rows);
        let n = 1 + rng.below(rows - start);
        let mut b = RowArena::zeros(quant, d, n);
        b.copy_rows(0, &a, start, n);
        let mut c = RowArena::zeros(quant, d, rows);
        c.copy_rows(start, &b, 0, n);
        let (fa, fc) = (a.to_f32(), c.to_f32());
        check_close(&fa[start * d..(start + n) * d],
                    &fc[start * d..(start + n) * d], 0.0, 0.0)?;
        // payload accounting matches the dtype
        let expect = rows * d * quant.elem_bytes();
        if a.payload_bytes() != expect {
            return Err(format!("payload {} != {expect}", a.payload_bytes()));
        }
        Ok(())
    });
}

#[test]
fn prop_gsm_roundtrip_any_problem() {
    property("gsm encode/parse roundtrip", 60, |rng| {
        let p = gsm_mini::Problem::sample(rng);
        let seq = gsm_mini::encode_sequence(&p);
        let a_pos = seq.iter().position(|&t| t == gsm_mini::T_A).unwrap();
        match gsm_mini::parse_answer(&seq[a_pos..]) {
            Some(ans) if ans == p.answer() => Ok(()),
            other => Err(format!("{p:?} -> {other:?}")),
        }
    });
}

#[test]
fn prop_task_batches_respect_masks() {
    property("task masks select supervised positions", 30, |rng| {
        let b = copyback::batch(4, 32, rng);
        for i in 0..4 {
            for t in 0..32 {
                let masked = b.mask[i * 32 + t] == 1.0;
                if masked != (t >= copyback::OFFSET_K) {
                    return Err(format!("copyback mask wrong at {t}"));
                }
            }
        }
        let kb = kvretrieval::batch(4, 24, rng);
        let per_row: Vec<f32> = (0..4)
            .map(|i| kb.mask[i * 24..(i + 1) * 24].iter().sum())
            .collect();
        if per_row.iter().all(|&x| x == 1.0) {
            Ok(())
        } else {
            Err(format!("kvret mask {per_row:?}"))
        }
    });
}

#[test]
fn prop_json_roundtrip_random_values() {
    property("json roundtrip", 40, |rng| {
        fn gen(rng: &mut Rng, depth: usize) -> Value {
            match if depth == 0 { rng.below(4) } else { rng.below(6) } {
                0 => Value::Null,
                1 => Value::Bool(rng.below(2) == 0),
                2 => Value::Num((rng.normal() * 100.0).round()),
                3 => Value::Str(format!("s{}\n\"{}\"", rng.below(100),
                                        rng.below(10))),
                4 => Value::Arr((0..rng.below(4))
                    .map(|_| gen(rng, depth - 1))
                    .collect()),
                _ => Value::Obj((0..rng.below(4))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect()),
            }
        }
        let v = gen(rng, 3);
        let parsed =
            Value::parse(&v.to_string()).map_err(|e| e.to_string())?;
        if parsed == v {
            Ok(())
        } else {
            Err(format!("{v:?} != {parsed:?}"))
        }
    });
}
