//! Fault-injection properties (ISSUE 7): randomized fault schedules over
//! randomized submit/step/churn sequences, asserting that transactional
//! rollback restores engine state EXACTLY after every injected failure —
//! `Engine::state_fingerprint()` unchanged (lane map, group arenas,
//! parked/chunking host mirrors, tracked rows), `invariant_violations()`
//! empty, and the token streams of recovered runs bit-identical to
//! fault-free runs of the same prompts.

use thinkeys::coordinator::engine::Engine;
use thinkeys::coordinator::kvcache::{KvCacheConfig, KvCacheManager};
use thinkeys::coordinator::router::synth_prompt;
use thinkeys::coordinator::sampling::Sampler;
use thinkeys::coordinator::scheduler::{SchedConfig, Scheduler};
use thinkeys::coordinator::sequence::Sequence;
use thinkeys::proptest::property;
use thinkeys::runtime::{FaultKind, FaultPlan, ParamStore, Runtime};
use thinkeys::substrate::rng::Rng;

fn runtime() -> Runtime {
    Runtime::new().expect("run `make artifacts` first")
}

fn engine<'a>(rt: &'a Runtime, cfg: &str, seed: u64) -> Engine<'a> {
    let params = ParamStore::init(rt.manifest().config(cfg).unwrap(), 42);
    Engine::new(rt, cfg, params, false, Sampler::Greedy, seed).unwrap()
}

fn kv_for(rt: &Runtime, cfg: &str, budget_mb: f64) -> KvCacheManager {
    let c = rt.manifest().config(cfg).unwrap();
    KvCacheManager::new(KvCacheConfig {
        n_layers: c.n_layers,
        k_dims: c.k_cache_dims,
        v_dims: c.v_cache_dims,
        block_tokens: 16,
        bytes_per_el_k: 2.0,
        bytes_per_el_v: 2.0,
        budget_bytes: budget_mb * 1e6,
    })
}

/// A plan that makes the NEXT erroring fault certain (probability 1.0 for
/// one kind, burst clamp effectively disabled).
fn forced(kind: FaultKind, seed: u64) -> FaultPlan {
    let mut p = FaultPlan { seed, max_burst: 1_000_000, ..FaultPlan::empty() };
    match kind {
        FaultKind::ExecFailure => p.exec = 1.0,
        FaultKind::ArtifactLoad => p.load = 1.0,
        FaultKind::CorruptOutput => p.corrupt = 1.0,
        FaultKind::FatalError => p.fatal = 1.0,
        FaultKind::LatencySpike | FaultKind::Wedge => {
            unreachable!("latency/wedge never error")
        }
    }
    p
}

/// A forced TRANSIENT-class kind (exec or artifact-load): guaranteed to
/// error the step without implicating any sequence, so a zero-progress
/// failed round leaves scheduler-owned state untouched too.
fn pick_transient(rng: &mut Rng) -> FaultKind {
    if rng.below(2) == 0 {
        FaultKind::ExecFailure
    } else {
        FaultKind::ArtifactLoad
    }
}

fn pick_kind(rng: &mut Rng) -> FaultKind {
    match rng.below(3) {
        0 => FaultKind::ExecFailure,
        1 => FaultKind::ArtifactLoad,
        _ => FaultKind::CorruptOutput,
    }
}

/// Forced decode failures roll the engine back exactly, consume no
/// sampler state, and the recovered run decodes bit-identical tokens to
/// a fault-free twin engine.
#[test]
fn forced_decode_failures_roll_back_exactly() {
    let rt = runtime();
    property("decode_rollback_exact", 6, |rng| {
        let cfg = "servethin";
        let vocab = rt.manifest().config(cfg).unwrap().vocab;
        let eng_seed = rng.next_u64();
        let mut eng = engine(&rt, cfg, eng_seed);
        let mut twin = engine(&rt, cfg, eng_seed);
        let n = 1 + rng.below(3);
        let mut seqs: Vec<Sequence> = (0..n)
            .map(|i| {
                let p = synth_prompt(4 + rng.below(16), vocab, rng);
                Sequence::new(i as u64 + 1, p, 4 + rng.below(4), None)
            })
            .collect();
        let mut twins: Vec<Sequence> = seqs.clone();
        for s in seqs.iter_mut() {
            eng.prefill(s).map_err(|e| e.to_string())?;
        }
        for s in twins.iter_mut() {
            twin.prefill(s).map_err(|e| e.to_string())?;
        }

        let mut injected_failures = 0usize;
        while seqs.iter().any(|s| !s.is_finished()) {
            // randomly interpose a forced failure before this step
            if rng.below(2) == 0 {
                rt.install_fault_plan(forced(pick_kind(rng), rng.next_u64()));
                let fp = eng.state_fingerprint();
                let toks_before: Vec<Vec<i32>> =
                    seqs.iter().map(|s| s.generated.clone()).collect();
                {
                    let mut live: Vec<&mut Sequence> =
                        seqs.iter_mut().filter(|s| !s.is_finished()).collect();
                    let r = eng.decode_step(&mut live);
                    if r.is_ok() {
                        return Err("forced fault did not fire".into());
                    }
                }
                if eng.state_fingerprint() != fp {
                    return Err("rollback did not restore engine state".into());
                }
                let v = eng.invariant_violations();
                if !v.is_empty() {
                    return Err(format!("violations after rollback: {v:?}"));
                }
                let toks_after: Vec<Vec<i32>> =
                    seqs.iter().map(|s| s.generated.clone()).collect();
                if toks_before != toks_after {
                    return Err("failed step mutated sequences".into());
                }
                injected_failures += 1;
                rt.install_fault_plan(FaultPlan::empty());
            }
            {
                let mut live: Vec<&mut Sequence> =
                    seqs.iter_mut().filter(|s| !s.is_finished()).collect();
                eng.decode_step(&mut live).map_err(|e| e.to_string())?;
            }
            let mut live: Vec<&mut Sequence> =
                twins.iter_mut().filter(|s| !s.is_finished()).collect();
            twin.decode_step(&mut live).map_err(|e| e.to_string())?;
        }
        if injected_failures == 0 {
            // at least exercise one failure per case for the property to
            // mean anything (the loop above flips a coin each step)
            rt.install_fault_plan(forced(pick_kind(rng), rng.next_u64()));
            let fp = eng.state_fingerprint();
            let mut one = Sequence::new(99, synth_prompt(6, vocab, rng), 4, None);
            if eng.prefill(&mut one).is_ok() {
                return Err("forced prefill fault did not fire".into());
            }
            if eng.state_fingerprint() != fp {
                return Err("prefill failure leaked engine state".into());
            }
            rt.install_fault_plan(FaultPlan::empty());
        }
        for (a, b) in seqs.iter().zip(&twins) {
            if a.generated != b.generated {
                return Err(format!(
                    "seq {} diverged from the fault-free twin: {:?} vs {:?}",
                    a.id, a.generated, b.generated));
            }
        }
        Ok(())
    });
}

/// Chunked-prefill failures — on the FIRST chunk and on resumed chunks —
/// leave the progress bookkeeping and host mirror exactly at the previous
/// chunk boundary, and the recovered ingest still matches the fault-free
/// twin bit-exactly.
#[test]
fn forced_chunk_failures_leave_prefill_at_chunk_boundary() {
    let rt = runtime();
    let chunk = *rt
        .manifest()
        .chunks_for("servethin")
        .first()
        .expect("servethin exports chunked prefill");
    property("chunk_rollback_exact", 6, |rng| {
        let cfg = "servethin";
        let vocab = rt.manifest().config(cfg).unwrap().vocab;
        let eng_seed = rng.next_u64();
        let mut eng = engine(&rt, cfg, eng_seed);
        let mut twin = engine(&rt, cfg, eng_seed);
        let n_chunks = 2 + rng.below(3);
        let p = synth_prompt(chunk * n_chunks - rng.below(chunk), vocab, rng);
        let mut seq = Sequence::new(1, p.clone(), 4, None);
        let mut twin_seq = Sequence::new(1, p, 4, None);

        let mut done = false;
        while !done {
            // randomly force this chunk to fail first
            if rng.below(2) == 0 {
                rt.install_fault_plan(forced(pick_kind(rng), rng.next_u64()));
                let fp = eng.state_fingerprint();
                let rows = eng.rows(seq.id);
                if eng.prefill_chunk(&mut seq, chunk).is_ok() {
                    return Err("forced chunk fault did not fire".into());
                }
                if eng.state_fingerprint() != fp {
                    return Err("chunk failure leaked engine state".into());
                }
                if eng.rows(seq.id) != rows {
                    return Err(format!(
                        "rows moved across a failed chunk: {} -> {}",
                        rows, eng.rows(seq.id)));
                }
                let v = eng.invariant_violations();
                if !v.is_empty() {
                    return Err(format!("violations after rollback: {v:?}"));
                }
                rt.install_fault_plan(FaultPlan::empty());
            }
            done = eng
                .prefill_chunk(&mut seq, chunk)
                .map_err(|e| e.to_string())?;
            let twin_done = twin
                .prefill_chunk(&mut twin_seq, chunk)
                .map_err(|e| e.to_string())?;
            if done != twin_done {
                return Err("chunk progress diverged from twin".into());
            }
        }
        // the first sampled token is part of the final chunk: recovered
        // ingest must match the fault-free twin exactly
        if seq.generated != twin_seq.generated {
            return Err(format!(
                "post-prefill tokens diverged: {:?} vs {:?}",
                seq.generated, twin_seq.generated));
        }
        Ok(())
    });
}

/// Scheduler-level churn under randomized moderate fault schedules:
/// submit/step/preempt sequences with a retry budget above the burst
/// clamp never escalate, never trip the auditor, and never leave the
/// engine with invariant violations.
#[test]
fn randomized_churn_under_random_fault_schedules_stays_consistent() {
    let rt = runtime();
    let chunk = rt.manifest().chunks_for("servethin").first().copied();
    property("churn_under_faults", 5, |rng| {
        let eng = engine(&rt, "servethin", rng.next_u64());
        let kv = kv_for(&rt, "servethin", 0.5);
        let vocab = eng.cfg.vocab;
        let mut sched = Scheduler::with_config(eng, kv, SchedConfig {
            max_batch: 6,
            round_budget: 48,
            chunk_tokens: if rng.below(2) == 0 { chunk } else { None },
            interactive_weight: 2,
            max_step_retries: 4,
            retry_backoff_us: 20,
            ..SchedConfig::default()
        });
        let plan = FaultPlan {
            seed: rng.next_u64(),
            exec: rng.f64() * 0.15,
            load: rng.f64() * 0.1,
            corrupt: rng.f64() * 0.1,
            latency: rng.f64() * 0.2,
            latency_us: 100,
            max_burst: 2,
            ..FaultPlan::empty()
        };
        rt.install_fault_plan(plan);
        let mut submitted = 0usize;
        for _ in 0..40 {
            match rng.below(5) {
                0 | 1 => {
                    let len = 2 + rng.below(20);
                    let max_new = 1 + rng.below(6);
                    sched.submit(synth_prompt(len, vocab, rng), max_new, None);
                    submitted += 1;
                }
                2 if sched.n_running() > 1 => {
                    sched.preempt_one();
                }
                _ => {}
            }
            // a Fatal escalation fails the property (retry budget 4 >
            // burst clamp 2 means every injected failure must recover)
            sched.step().map_err(|e| format!("step escalated: {e:#}"))?;
            let v = sched.engine.invariant_violations();
            if !v.is_empty() {
                return Err(format!("violations mid-churn: {v:?}"));
            }
        }
        rt.install_fault_plan(FaultPlan::empty());
        sched
            .run_to_completion()
            .map_err(|e| format!("drain escalated: {e:#}"))?;
        let finished = sched.finished.len();
        if finished != submitted {
            return Err(format!(
                "{submitted} submitted but {finished} accounted for"));
        }
        if sched.engine.metrics.sync_download_bytes != 0 {
            return Err("recovery resorted to full-arena downloads".into());
        }
        Ok(())
    });
}

/// Satellite 3 (ISSUE 9): rollback exactness across the PR 8 paged-KV
/// states the snapshot machinery predates — adopted shared prefixes,
/// forked CoW children (shared full blocks + privately copied partial
/// tail), live refcounts > 1. A forced transient failure in that state
/// must leave the engine fingerprint, the invariants, AND the block
/// refcounts untouched, and the recovered run must decode bit-identical
/// to a fault-free twin driven through the same submit/fork schedule.
#[test]
fn rollback_exactness_holds_across_paged_kv_states() {
    let rt = runtime();
    let chunk = rt.manifest().chunks_for("servethin").first().copied();
    property("paged_state_rollback_exact", 4, |rng| {
        let eng_seed = rng.next_u64();
        let cfg = SchedConfig {
            max_batch: 6,
            round_budget: 64,
            chunk_tokens: chunk,
            max_step_retries: 4,
            retry_backoff_us: 20,
            ..SchedConfig::default()
        };
        let mut sched = Scheduler::with_config(
            engine(&rt, "servethin", eng_seed),
            kv_for(&rt, "servethin", 0.5),
            cfg,
        );
        let mut twin = Scheduler::with_config(
            engine(&rt, "servethin", eng_seed),
            kv_for(&rt, "servethin", 0.5),
            cfg,
        );
        let vocab = sched.engine.cfg.vocab;
        // one shared 24-token prefix (1 full block + a partial tail at
        // block_tokens=16) under three distinct continuations
        let prefix = synth_prompt(24, vocab, rng);
        let mut prompts: Vec<Vec<i32>> = Vec::new();
        for i in 0..3usize {
            let mut p = prefix.clone();
            p.extend(synth_prompt(3 + i, vocab, rng));
            prompts.push(p);
        }
        // user 1 first, alone, so its prefix is sealed and registered
        // before users 2/3 admit — forcing the adoption fast path
        sched.submit(prompts[0].clone(), 8, None);
        twin.submit(prompts[0].clone(), 8, None);
        let mut rounds = 0usize;
        while sched.n_running() < 1 && rounds < 30 {
            sched.step().map_err(|e| format!("step: {e:#}"))?;
            twin.step().map_err(|e| format!("twin step: {e:#}"))?;
            rounds += 1;
        }
        for p in &prompts[1..] {
            sched.submit(p.clone(), 8, None);
            twin.submit(p.clone(), 8, None);
        }
        // drive lockstep until the cohort is fully admitted and decoding
        while (sched.n_waiting() > 0 || sched.n_prefilling() > 0)
            && rounds < 60
        {
            sched.step().map_err(|e| format!("step: {e:#}"))?;
            twin.step().map_err(|e| format!("twin step: {e:#}"))?;
            rounds += 1;
        }
        if sched.n_running() < 2 {
            return Err(format!(
                "cohort never co-resident: {} running after {rounds} rounds",
                sched.n_running()
            ));
        }
        if sched.engine.metrics.prefix_hits == 0 {
            return Err("users 2/3 never adopted the sealed prefix".into());
        }
        // fork the lowest running id in BOTH runs: CoW shared history +
        // private partial-tail copy, refcounts > 1 while both live
        let parent = *sched
            .running_ids()
            .first()
            .expect("running checked non-empty");
        sched.fork(parent, 4).map_err(|e| format!("fork: {e:#}"))?;
        twin.fork(parent, 4).map_err(|e| format!("twin fork: {e:#}"))?;
        if sched.kv.sharing_stats().shared_blocks == 0 {
            return Err("fork shared no blocks with its parent".into());
        }

        // the pinned interaction: a forced transient failure while the
        // engine holds adopted-prefix AND forked-CoW state
        rt.install_fault_plan(forced(pick_transient(rng), rng.next_u64()));
        let fp = sched.engine.state_fingerprint();
        let rc = sched.kv.refcount_violations();
        if sched.step().is_ok() {
            return Err("forced transient plan did not escalate".into());
        }
        if sched.engine.state_fingerprint() != fp {
            return Err(
                "rollback did not restore the paged-state fingerprint".into(),
            );
        }
        let v = sched.engine.invariant_violations();
        if !v.is_empty() {
            return Err(format!("violations after rollback: {v:?}"));
        }
        if sched.kv.refcount_violations() != rc {
            return Err("failed step disturbed block refcounts".into());
        }
        rt.install_fault_plan(FaultPlan::empty());

        // recovery: both runs drain, and every sequence (including the
        // forked children) decodes bit-identical tokens
        sched
            .run_to_completion()
            .map_err(|e| format!("drain: {e:#}"))?;
        twin
            .run_to_completion()
            .map_err(|e| format!("twin drain: {e:#}"))?;
        if sched.finished.len() != 4 || twin.finished.len() != 4 {
            return Err(format!(
                "expected 4 finished (3 users + 1 fork): {} vs {}",
                sched.finished.len(),
                twin.finished.len()
            ));
        }
        let toks = |s: &Scheduler| -> Vec<(u64, Vec<i32>)> {
            let mut v: Vec<(u64, Vec<i32>)> = s
                .finished
                .iter()
                .map(|q| (q.id, q.generated.clone()))
                .collect();
            v.sort();
            v
        };
        if toks(&sched) != toks(&twin) {
            return Err("recovered run diverged from fault-free twin".into());
        }
        if sched.engine.metrics.sync_download_bytes != 0 {
            return Err("recovery resorted to full-arena downloads".into());
        }
        Ok(())
    });
}
