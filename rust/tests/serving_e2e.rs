//! Serving-stack integration: engine prefill/decode correctness against
//! the logits oracle, continuous batching under membership churn, KV
//! accounting, and the factored-key serving path.

use thinkeys::coordinator::engine::Engine;
use thinkeys::coordinator::kvcache::{KvCacheConfig, KvCacheManager};
use thinkeys::coordinator::router::{synth_prompt, Router};
use thinkeys::coordinator::sampling::Sampler;
use thinkeys::coordinator::scheduler::{SchedConfig, Scheduler};
use thinkeys::coordinator::sequence::{FinishReason, Priority, SeqState,
                                      Sequence};
use thinkeys::datagen::arrival::closed_loop;
use thinkeys::datagen::Batch;
use thinkeys::model::surgery;
use thinkeys::runtime::{KvQuant, ParamStore, Runtime};
use thinkeys::substrate::mathutil::argmax;
use thinkeys::substrate::rng::Rng;
use thinkeys::train::eval::logits_for;

fn runtime() -> Runtime {
    Runtime::new().expect("run `make artifacts` first")
}

fn engine<'a>(rt: &'a Runtime, cfg: &str, seed: u64) -> Engine<'a> {
    let params = ParamStore::init(rt.manifest().config(cfg).unwrap(), 42);
    Engine::new(rt, cfg, params, false, Sampler::Greedy, seed).unwrap()
}

fn kv_for(rt: &Runtime, cfg: &str, budget_mb: f64) -> KvCacheManager {
    let c = rt.manifest().config(cfg).unwrap();
    KvCacheManager::new(KvCacheConfig {
        n_layers: c.n_layers,
        k_dims: c.k_cache_dims,
        v_dims: c.v_cache_dims,
        block_tokens: 16,
        bytes_per_el_k: 2.0,
        bytes_per_el_v: 2.0,
        budget_bytes: budget_mb * 1e6,
    })
}

/// The engine's greedy generation must match teacher-forced greedy argmax
/// through the logits artifact (prefill/decode == forward parity, but now
/// through the serving path with batching and cache packing).
#[test]
fn engine_matches_teacher_forced_greedy() {
    let rt = runtime();
    let cfg = rt.manifest().config("servefull").unwrap().clone();
    let mut eng = engine(&rt, "servefull", 0);
    let mut rng = Rng::new(9);
    let prompt = synth_prompt(12, cfg.vocab, &mut rng);
    let mut seq = Sequence::new(1, prompt.clone(), 6, None);
    eng.prefill(&mut seq).unwrap();
    while !seq.is_finished() {
        let mut seqs = vec![&mut seq];
        eng.decode_step(&mut seqs).unwrap();
    }
    assert_eq!(seq.generated.len(), 6);

    // teacher-forced reference: extend the prompt token by token via the
    // logits artifact and take argmax each step
    let params = ParamStore::init(&cfg, 42);
    let (b, s) = (cfg.train_batch, cfg.train_seq);
    let _ = b;
    let mut toks = prompt.clone();
    let mut want = Vec::new();
    for _ in 0..6 {
        let mut batch = Batch::zeros(cfg.train_batch, s);
        for (t, &x) in toks.iter().enumerate() {
            batch.tokens[t] = x;
        }
        let logits = logits_for(&rt, &cfg, &params, &batch).unwrap();
        let pos = toks.len() - 1;
        let row = &logits.data[pos * cfg.vocab..(pos + 1) * cfg.vocab];
        let next = argmax(row) as i32;
        want.push(next);
        toks.push(next);
    }
    assert_eq!(seq.generated, want,
               "engine generation diverged from teacher-forced reference");
}

/// Two sequences decoded together must produce the same tokens as each
/// decoded alone (batching must not leak state across lanes).
#[test]
fn batched_decode_matches_individual() {
    let rt = runtime();
    let cfg = rt.manifest().config("servethin").unwrap().clone();
    let mut rng = Rng::new(3);
    let p1 = synth_prompt(10, cfg.vocab, &mut rng);
    let p2 = synth_prompt(17, cfg.vocab, &mut rng);

    let run_alone = |prompt: &Vec<i32>| {
        let mut eng = engine(&rt, "servethin", 0);
        let mut seq = Sequence::new(1, prompt.clone(), 5, None);
        eng.prefill(&mut seq).unwrap();
        while !seq.is_finished() {
            let mut seqs = vec![&mut seq];
            eng.decode_step(&mut seqs).unwrap();
        }
        seq.generated
    };
    let alone1 = run_alone(&p1);
    let alone2 = run_alone(&p2);

    let mut eng = engine(&rt, "servethin", 0);
    let mut s1 = Sequence::new(1, p1, 5, None);
    let mut s2 = Sequence::new(2, p2, 5, None);
    eng.prefill(&mut s1).unwrap();
    eng.prefill(&mut s2).unwrap();
    while !s1.is_finished() || !s2.is_finished() {
        let mut seqs: Vec<&mut Sequence> = Vec::new();
        if !s1.is_finished() {
            seqs.push(&mut s1);
        }
        if !s2.is_finished() {
            seqs.push(&mut s2);
        }
        eng.decode_step(&mut seqs).unwrap();
    }
    assert_eq!(s1.generated, alone1, "lane 0 diverged under batching");
    assert_eq!(s2.generated, alone2, "lane 1 diverged under batching");
}

/// Membership churn: a sequence joining mid-flight (regroup + repack) must
/// not corrupt the cache of already-running sequences.
#[test]
fn regroup_preserves_cache_state() {
    let rt = runtime();
    let cfg = rt.manifest().config("servefull").unwrap().clone();
    let mut rng = Rng::new(5);
    let p1 = synth_prompt(8, cfg.vocab, &mut rng);
    let p2 = synth_prompt(8, cfg.vocab, &mut rng);

    let alone = {
        let mut eng = engine(&rt, "servefull", 0);
        let mut seq = Sequence::new(1, p1.clone(), 8, None);
        eng.prefill(&mut seq).unwrap();
        while !seq.is_finished() {
            let mut seqs = vec![&mut seq];
            eng.decode_step(&mut seqs).unwrap();
        }
        seq.generated
    };

    let mut eng = engine(&rt, "servefull", 0);
    let mut s1 = Sequence::new(1, p1, 8, None);
    eng.prefill(&mut s1).unwrap();
    // decode 3 steps solo
    for _ in 0..3 {
        let mut seqs = vec![&mut s1];
        eng.decode_step(&mut seqs).unwrap();
    }
    // second sequence joins: bucket 1 -> 2, full repack
    let mut s2 = Sequence::new(2, p2, 4, None);
    eng.prefill(&mut s2).unwrap();
    while !s1.is_finished() {
        let mut seqs: Vec<&mut Sequence> = vec![&mut s1];
        if !s2.is_finished() {
            seqs.push(&mut s2);
        }
        eng.decode_step(&mut seqs).unwrap();
    }
    assert_eq!(s1.generated, alone,
               "regroup corrupted a running sequence's cache");
    assert!(eng.metrics.regroups >= 2);
}

/// Factored serving: surgery weights on the thin artifact family generate
/// and the thin K arena is 4x smaller.
#[test]
fn factored_serving_path_works() {
    let rt = runtime();
    let m = rt.manifest();
    let full_cfg = m.config("servefull").unwrap().clone();
    let thin_cfg = m.config("servethin").unwrap().clone();
    let full = ParamStore::init(&full_cfg, 42);
    let thin = surgery::factor_to_thin(&full, &full_cfg, &thin_cfg).unwrap();
    let mut eng =
        Engine::new(&rt, "servethin", thin, false, Sampler::Greedy, 0).unwrap();
    let mut rng = Rng::new(1);
    let mut seq =
        Sequence::new(1, synth_prompt(20, thin_cfg.vocab, &mut rng), 8, None);
    eng.prefill(&mut seq).unwrap();
    while !seq.is_finished() {
        let mut seqs = vec![&mut seq];
        eng.decode_step(&mut seqs).unwrap();
    }
    assert_eq!(seq.generated.len(), 8);
    assert_eq!(thin_cfg.k_cache_dims * 4, full_cfg.k_cache_dims);
}

/// Full router stack: closed-loop trace completes, metrics populated, KV
/// accounting returns to empty.
#[test]
fn router_closed_loop_end_to_end() {
    let rt = runtime();
    let eng = engine(&rt, "servethin", 7);
    let kv = kv_for(&rt, "servethin", 4.0);
    let sched = Scheduler::new(eng, kv, 8);
    let mut router = Router::new(sched);
    let trace = closed_loop(12, 24, 8);
    let report = router.run_closed_loop(&trace, 0).unwrap();
    assert_eq!(report.n_requests, 12);
    assert_eq!(report.rejected, 0);
    assert_eq!(report.gen_tokens, 12 * 8);
    assert!(report.gen_tokens_per_sec() > 0.0);
    assert!(report.ttft.count() == 12 && report.e2e.count() == 12);
    let stats = router.sched.kv.stats();
    assert_eq!(stats.seqs, 0, "cache not fully released: {stats:?}");
    assert!(router.sched.engine.metrics.mean_occupancy() > 0.3);
}

/// THE lane-misalignment regression: retiring the sequence in lane 0 must
/// not shift the survivor's decode feed. Under the old enumeration-based
/// lane assignment this test fails — after seq 1 retired, seq 2's tokens
/// were fed into lane 0 while its cache rows lived in lane 1, silently
/// corrupting its generation. The lane map keeps the survivor in lane 1
/// with zero bytes copied.
#[test]
fn retirement_keeps_surviving_lanes_aligned() {
    let rt = runtime();
    let cfg = rt.manifest().config("servefull").unwrap().clone();
    let mut rng = Rng::new(13);
    let p1 = synth_prompt(6, cfg.vocab, &mut rng);
    let p2 = synth_prompt(9, cfg.vocab, &mut rng);

    let alone2 = {
        let mut eng = engine(&rt, "servefull", 0);
        let mut seq = Sequence::new(2, p2.clone(), 10, None);
        eng.prefill(&mut seq).unwrap();
        while !seq.is_finished() {
            let mut seqs = vec![&mut seq];
            eng.decode_step(&mut seqs).unwrap();
        }
        seq.generated
    };

    let mut eng = engine(&rt, "servefull", 0);
    let mut s1 = Sequence::new(1, p1, 2, None);
    let mut s2 = Sequence::new(2, p2, 10, None);
    eng.prefill(&mut s1).unwrap();
    eng.prefill(&mut s2).unwrap();
    while !s1.is_finished() {
        let mut seqs: Vec<&mut Sequence> = vec![&mut s1, &mut s2];
        eng.decode_step(&mut seqs).unwrap();
    }
    assert_eq!(eng.lane_of(1), Some(0));
    assert_eq!(eng.lane_of(2), Some(1));
    // retire lane 0 exactly the way the scheduler does
    eng.drop_seq(1);
    let copied_before = eng.metrics.copyback_bytes;
    while !s2.is_finished() {
        let mut seqs = vec![&mut s2];
        eng.decode_step(&mut seqs).unwrap();
    }
    assert_eq!(eng.lane_of(2), Some(1), "survivor's lane moved");
    assert_eq!(eng.metrics.copyback_bytes, copied_before,
               "zero-copy retirement copied bytes");
    assert_eq!(s2.generated, alone2,
               "decode fed the survivor's tokens into the wrong lane");
}

/// Acceptance: a steady-state single retirement at B=8 copies O(changed
/// lanes) — zero bytes here — while the full park/unpark baseline copies
/// every surviving lane out and back in (>= 4x more).
#[test]
fn single_retirement_copyback_is_incremental() {
    let rt = runtime();
    let cfg = rt.manifest().config("servethin").unwrap().clone();
    let mut eng = engine(&rt, "servethin", 0);
    let mut rng = Rng::new(8);
    let mut seqs: Vec<Sequence> = (0..8)
        .map(|i| {
            let max_new = if i == 0 { 2 } else { 10 };
            Sequence::new(i as u64 + 1,
                          synth_prompt(12, cfg.vocab, &mut rng),
                          max_new, None)
        })
        .collect();
    for s in seqs.iter_mut() {
        eng.prefill(s).unwrap();
    }
    while !seqs[0].is_finished() {
        let mut refs: Vec<&mut Sequence> =
            seqs.iter_mut().filter(|s| !s.is_finished()).collect();
        eng.decode_step(&mut refs).unwrap();
    }
    let (a0, f0) =
        (eng.metrics.copyback_bytes, eng.metrics.copyback_bytes_full);
    eng.drop_seq(1);
    for _ in 0..3 {
        let mut refs: Vec<&mut Sequence> =
            seqs.iter_mut().filter(|s| !s.is_finished()).collect();
        eng.decode_step(&mut refs).unwrap();
    }
    let actual = eng.metrics.copyback_bytes - a0;
    let full = eng.metrics.copyback_bytes_full - f0;
    assert_eq!(actual, 0, "steady-state retirement copied {actual} bytes");
    assert!(full > 0, "baseline accounting missed the membership change");
    assert!(full >= 4 * actual.max(1),
            "copy savings below 4x: {actual} vs {full}");
    assert_eq!(eng.lane_of(1), None);
    for id in 2..=8u64 {
        assert!(eng.lane_of(id).is_some(), "survivor {id} lost its lane");
    }
}

/// THE delta-sync acceptance test: under steady membership churn
/// (retire → join cycles) the engine never downloads the full cache
/// arenas — the host mirror is kept current from the per-step delta rows
/// — uploads happen only on membership changes, per-step host traffic is
/// O(L·B) (independent of max_seq), and survivors' generations stay
/// byte-identical to solo runs.
#[test]
fn steady_churn_is_delta_synced() {
    let rt = runtime();
    let cfg = rt.manifest().config("servethin").unwrap().clone();
    let mut rng = Rng::new(21);
    let p_short = synth_prompt(7, cfg.vocab, &mut rng);
    let p_long = synth_prompt(11, cfg.vocab, &mut rng);
    let p_join = synth_prompt(9, cfg.vocab, &mut rng);

    let alone = {
        let mut eng = engine(&rt, "servethin", 0);
        let mut seq = Sequence::new(2, p_long.clone(), 14, None);
        eng.prefill(&mut seq).unwrap();
        while !seq.is_finished() {
            let mut seqs = vec![&mut seq];
            eng.decode_step(&mut seqs).unwrap();
        }
        seq.generated
    };

    let mut eng = engine(&rt, "servethin", 0);
    let mut s1 = Sequence::new(1, p_short, 3, None);
    let mut s2 = Sequence::new(2, p_long, 14, None);
    eng.prefill(&mut s1).unwrap();
    eng.prefill(&mut s2).unwrap();
    while !s1.is_finished() {
        let mut seqs: Vec<&mut Sequence> = vec![&mut s1, &mut s2];
        eng.decode_step(&mut seqs).unwrap();
    }
    // retire s1 (hole), decode s2 alone for a few steps: steady state,
    // no uploads
    eng.drop_seq(1);
    for _ in 0..3 {
        let mut seqs = vec![&mut s2];
        eng.decode_step(&mut seqs).unwrap();
    }
    let upload_steady = eng.metrics.sync_upload_bytes;
    for _ in 0..2 {
        let mut seqs = vec![&mut s2];
        eng.decode_step(&mut seqs).unwrap();
    }
    assert_eq!(eng.metrics.sync_upload_bytes, upload_steady,
               "steady-state decode uploaded arena bytes");
    // a joiner reuses the hole: exactly one more upload, still zero
    // downloads
    let mut s3 = Sequence::new(3, p_join, 6, None);
    eng.prefill(&mut s3).unwrap();
    while !s2.is_finished() {
        let mut seqs: Vec<&mut Sequence> = vec![&mut s2];
        if !s3.is_finished() {
            seqs.push(&mut s3);
        }
        eng.decode_step(&mut seqs).unwrap();
    }
    assert!(eng.metrics.sync_upload_bytes > upload_steady,
            "join must re-upload the repacked arenas");
    assert_eq!(eng.metrics.sync_download_bytes, 0,
               "delta-synced mirror must never download the full arenas");
    assert_eq!(s2.generated, alone,
               "churn (retire + join) corrupted the survivor's cache");
    // per-step host traffic is O(L·B·(KD+VD)) — no max_seq term (the
    // bucket never exceeded 2 in this run)
    let m = &eng.metrics;
    let lane_row = cfg.n_layers * (cfg.k_cache_dims + cfg.v_cache_dims) * 4;
    assert!(m.row_sync_bytes > 0);
    assert!(m.row_sync_bytes_per_step() <= (2 * lane_row) as f64,
            "per-step delta sync moved more than L*B*(KD+VD) bytes");
}

/// A sequence growing across a tier boundary mid-generation: the arena
/// must grow (tier switch), the kept rows must move intact, and the
/// generation must still match the teacher-forced reference.
#[test]
fn tier_growth_preserves_generation() {
    let rt = runtime();
    let cfg = rt.manifest().config("servefull").unwrap().clone();
    let mut eng = engine(&rt, "servefull", 0);
    let mut rng = Rng::new(17);
    let prompt = synth_prompt(12, cfg.vocab, &mut rng);
    let gen = 30; // 12 + 30 = 42 rows: crosses the n=32 tier into n=64
    let mut seq = Sequence::new(1, prompt.clone(), gen, None);
    eng.prefill(&mut seq).unwrap();
    while !seq.is_finished() {
        let mut seqs = vec![&mut seq];
        eng.decode_step(&mut seqs).unwrap();
    }
    assert!(eng.metrics.tier_switches >= 1, "no tier growth recorded");
    assert_eq!(eng.current_tier(), 64);
    assert_eq!(eng.metrics.sync_download_bytes, 0);

    // teacher-forced greedy reference through the logits artifact
    let params = ParamStore::init(&cfg, 42);
    let s = cfg.train_seq;
    let mut toks = prompt;
    let mut want = Vec::new();
    for _ in 0..gen {
        let mut batch = Batch::zeros(cfg.train_batch, s);
        for (t, &x) in toks.iter().enumerate() {
            batch.tokens[t] = x;
        }
        let logits = logits_for(&rt, &cfg, &params, &batch).unwrap();
        let pos = toks.len() - 1;
        let row = &logits.data[pos * cfg.vocab..(pos + 1) * cfg.vocab];
        let next = argmax(row) as i32;
        want.push(next);
        toks.push(next);
    }
    assert_eq!(seq.generated, want,
               "tier growth corrupted the decode cache");
}

/// When the long sequence retires, the arena shrinks back (with 2x
/// headroom hysteresis) and the short survivor's generation is unchanged
/// — shrink copies the kept rows correctly and never downloads.
#[test]
fn tier_shrinks_after_long_sequence_retires() {
    let rt = runtime();
    let cfg = rt.manifest().config("servethin").unwrap().clone();
    let mut rng = Rng::new(23);
    let p_doc = synth_prompt(90, cfg.vocab, &mut rng);
    let p_chat = synth_prompt(10, cfg.vocab, &mut rng);

    let alone = {
        let mut eng = engine(&rt, "servethin", 0);
        let mut seq = Sequence::new(2, p_chat.clone(), 30, None);
        eng.prefill(&mut seq).unwrap();
        while !seq.is_finished() {
            let mut seqs = vec![&mut seq];
            eng.decode_step(&mut seqs).unwrap();
        }
        seq.generated
    };

    let mut eng = engine(&rt, "servethin", 0);
    let mut doc = Sequence::new(1, p_doc, 4, None);
    let mut chat = Sequence::new(2, p_chat, 30, None);
    eng.prefill(&mut doc).unwrap();
    eng.prefill(&mut chat).unwrap();
    while !doc.is_finished() {
        let mut seqs: Vec<&mut Sequence> = vec![&mut doc, &mut chat];
        eng.decode_step(&mut seqs).unwrap();
    }
    // the doc (94 rows) forced tier 128; once it retires the chat
    // (~15 rows) shrinks the arena with 2x headroom
    assert_eq!(eng.current_tier(), 128);
    eng.drop_seq(1);
    let switches_before = eng.metrics.tier_switches;
    while !chat.is_finished() {
        let mut seqs = vec![&mut chat];
        eng.decode_step(&mut seqs).unwrap();
    }
    assert!(eng.metrics.tier_switches > switches_before,
            "arena never shrank after the long sequence retired");
    assert!(eng.current_tier() < 128,
            "tier stuck at {}", eng.current_tier());
    assert_eq!(eng.metrics.sync_download_bytes, 0);
    assert_eq!(chat.generated, alone,
               "tier shrink corrupted the survivor's cache");
}

/// THE chunked-prefill parity acceptance (ISSUE 3): for EVERY chunk size
/// in the manifest — including a prompt not divisible by the chunk and
/// one shorter than it — chunked prefill must produce BIT-IDENTICAL
/// last-logits and parked mirror rows to the single-shot prefill, and the
/// decode generation that follows must be identical token for token.
#[test]
fn chunked_prefill_matches_single_shot_bit_exact() {
    let rt = runtime();
    for cfg_name in ["servefull", "servethin"] {
        let cfg = rt.manifest().config(cfg_name).unwrap().clone();
        let chunks = rt.manifest().chunks_for(cfg_name);
        assert!(!chunks.is_empty(), "no chunk artifacts for {cfg_name}");
        for plen in [8usize, 37, 128] {
            let mut rng = Rng::new(plen as u64);
            let prompt = synth_prompt(plen, cfg.vocab, &mut rng);

            // single-shot reference
            let mut eng_a = engine(&rt, cfg_name, 0);
            let mut sa = Sequence::new(1, prompt.clone(), 6, None);
            eng_a.prefill(&mut sa).unwrap();
            let logits_a = eng_a.last_prefill_logits().unwrap().data.clone();
            let (len_a, k_a, v_a) = eng_a.parked_snapshot(1).unwrap();
            while !sa.is_finished() {
                let mut seqs = vec![&mut sa];
                eng_a.decode_step(&mut seqs).unwrap();
            }

            for &c in &chunks {
                let mut eng_b = engine(&rt, cfg_name, 0);
                let mut sb = Sequence::new(1, prompt.clone(), 6, None);
                let mut calls = 0usize;
                loop {
                    let done = eng_b.prefill_chunk(&mut sb, c).unwrap();
                    calls += 1;
                    if done {
                        break;
                    }
                    // mid-prefill, the unified accounting sees the
                    // chunked progress, not 0 and not the full prompt
                    assert_eq!(eng_b.prefill_progress(1), Some(calls * c));
                    assert_eq!(eng_b.rows(1), calls * c);
                }
                assert_eq!(calls, plen.div_ceil(c), "{cfg_name} c={c}");
                assert_eq!(eng_b.prefill_progress(1), None);
                assert_eq!(eng_b.rows(1), plen);
                assert_eq!(
                    eng_b.last_prefill_logits().unwrap().data, logits_a,
                    "{cfg_name} plen={plen} c={c}: logits diverged"
                );
                let (len_b, k_b, v_b) = eng_b.parked_snapshot(1).unwrap();
                assert_eq!(len_b, len_a);
                assert!(k_b == k_a && v_b == v_a,
                        "{cfg_name} plen={plen} c={c}: mirror rows diverged");
                // same first token, same decode generation afterwards
                while !sb.is_finished() {
                    let mut seqs = vec![&mut sb];
                    eng_b.decode_step(&mut seqs).unwrap();
                }
                assert_eq!(sb.generated, sa.generated,
                           "{cfg_name} plen={plen} c={c}: generation \
                            diverged after chunked prefill");
            }
        }
    }
}

/// Priority preemption at the chunk boundary: a chat arriving while a
/// document is mid-ingestion gets the next chunk grant (and its first
/// token) while the document prefill stays parked — the document resumes
/// afterwards and completes untouched.
#[test]
fn interactive_preempts_batch_at_chunk_boundary() {
    let rt = runtime();
    let eng = engine(&rt, "servethin", 0);
    let kv = kv_for(&rt, "servethin", 4.0);
    let chunk = *rt.manifest().chunks_for("servethin").first().unwrap();
    let mut sched = Scheduler::with_config(eng, kv, SchedConfig {
        max_batch: 8,
        round_budget: 64,
        chunk_tokens: Some(chunk),
        interactive_weight: 4,
        ..SchedConfig::default()
    });
    let vocab = sched.engine.cfg.vocab;
    let mut rng = Rng::new(31);
    let doc_prompt = synth_prompt(chunk * 4, vocab, &mut rng);
    let doc = sched.submit_seq(doc_prompt, 4, None, Priority::Batch, None);
    sched.step().unwrap(); // doc ingests chunk 1 of 4
    assert_eq!(sched.n_prefilling(), 1);
    assert_eq!(sched.engine.prefill_progress(doc), Some(chunk));

    let chat_prompt = synth_prompt(chunk / 2, vocab, &mut rng);
    let chat = sched
        .submit_seq(chat_prompt, 4, None, Priority::Interactive, None);
    sched.step().unwrap();
    // the chunk grant went to the chat (admission + single-chunk prefill
    // + first decode step), NOT to the in-flight document
    assert_eq!(sched.n_running(), 1, "chat not decoding");
    assert_eq!(sched.n_prefilling(), 1, "doc prefill was not parked");
    assert_eq!(sched.engine.prefill_progress(doc), Some(chunk),
               "doc advanced past the chunk boundary during preemption");

    sched.run_to_completion().unwrap();
    let by_id = |id| {
        sched.finished.iter().find(|s| s.id == id).unwrap().clone()
    };
    let (doc_seq, chat_seq) = (by_id(doc), by_id(chat));
    assert_eq!(chat_seq.generated.len(), 4);
    assert_eq!(doc_seq.generated.len(), 4);
    assert!(chat_seq.first_token_at.unwrap() < doc_seq.first_token_at.unwrap(),
            "interactive chat did not get its first token before the doc");
    assert!(sched.engine.metrics.prefill_chunks >= 5);
    assert_eq!(sched.kv.stats().seqs, 0);
}

/// THE Batch-starvation regression (ROADMAP open item, fixed in ISSUE 5):
/// a queued Batch document behind a STEADY stream of admissible
/// Interactive chats must still be admitted and prefilled while the
/// stream continues. Under the old fixed Interactive-first
/// `next_admissible` scan this test fails: the anti-starvation boost
/// fired, but the pick loop's waiting arm only ever saw the Interactive
/// head, so the document sat at zero prefill progress for as long as
/// chats kept arriving. The class-targeted `admissible_in_class` probe
/// lets the boosted Batch grant admit the document's own head-of-line.
#[test]
fn batch_doc_survives_sustained_interactive_stream() {
    let rt = runtime();
    let eng = engine(&rt, "servethin", 0);
    let kv = kv_for(&rt, "servethin", 4.0);
    let chunk = *rt.manifest().chunks_for("servethin").first().unwrap();
    let mut sched = Scheduler::with_config(eng, kv, SchedConfig {
        max_batch: 8,
        round_budget: 64,
        chunk_tokens: Some(chunk),
        interactive_weight: 2,
        ..SchedConfig::default()
    });
    let vocab = sched.engine.cfg.vocab;
    let mut rng = Rng::new(47);
    let doc = sched.submit_seq(synth_prompt(chunk * 4, vocab, &mut rng), 2,
                               None, Priority::Batch, None);
    // one fresh admissible chat per round, every round — the Interactive
    // class never drains, so only a class-targeted boosted grant can
    // reach the waiting document
    let mut first_progress_round = None;
    let mut doc_done_round = None;
    let rounds = 30;
    for round in 0..rounds {
        sched.submit_seq(synth_prompt(4, vocab, &mut rng), 1, None,
                         Priority::Interactive, None);
        sched.step().unwrap();
        if first_progress_round.is_none() && sched.engine.rows(doc) > 0 {
            first_progress_round = Some(round);
        }
        if doc_done_round.is_none()
            && sched.finished.iter().any(|s| s.id == doc)
        {
            doc_done_round = Some(round);
        }
    }
    assert!(
        first_progress_round.is_some(),
        "Batch doc starved: zero prefill progress across {rounds} rounds \
         of sustained admissible Interactive load"
    );
    // the doc must have prefilled AND generated DURING the stream, not
    // only after the chats ran out
    assert!(
        doc_done_round.is_some(),
        "doc never completed while the chat stream was live \
         (first prefill progress at round {first_progress_round:?})"
    );
    // and the chats kept flowing — anti-starvation must not invert into
    // chat starvation: the doc consumes exactly ceil(prompt/chunk)
    // boosted rounds, every other round serves one full chat (single
    // chunk + one token), so at most doc_grants + a couple of boundary
    // rounds of the stream go un-served
    let doc_grants = (chunk * 4).div_ceil(chunk);
    assert!(sched.finished.iter()
                .filter(|s| s.priority == Priority::Interactive)
                .count() >= rounds - doc_grants - 2,
            "interactive chats starved by the batch grants");
    sched.run_to_completion().unwrap();
    let doc_seq = sched.finished.iter().find(|s| s.id == doc).unwrap();
    assert_eq!(doc_seq.generated.len(), 2, "doc never generated");
    assert_eq!(sched.kv.stats().seqs, 0);
}

/// The stall-flush fix (ISSUE 3 satellite): a waiting request that does
/// not fit only because an in-flight chunked prefill still holds its
/// reservation must NOT be evicted as "never fitting" — it is re-checked
/// once the prefill completes and retires, and then serves normally.
#[test]
fn waiting_request_survives_inflight_prefill_pressure() {
    let rt = runtime();
    let eng = engine(&rt, "servethin", 0);
    // capacity 192 tokens: doc reserves 128, chat needs 80 — the chat
    // fits the cache alone but NOT next to the doc
    let kv = kv_for(&rt, "servethin", 0.0922);
    assert_eq!(kv.total_token_capacity(), 192);
    let mut sched = Scheduler::with_config(eng, kv, SchedConfig {
        max_batch: 8,
        round_budget: 64,
        chunk_tokens: Some(16),
        interactive_weight: 4,
        ..SchedConfig::default()
    });
    let vocab = sched.engine.cfg.vocab;
    let mut rng = Rng::new(5);
    let doc = sched.submit_seq(
        synth_prompt(120, vocab, &mut rng), 8, None, Priority::Batch, None);
    sched.step().unwrap(); // doc admitted, chunk 1 in flight
    assert_eq!(sched.n_prefilling(), 1);
    let chat = sched.submit_seq(
        synth_prompt(72, vocab, &mut rng), 8, None,
        Priority::Interactive, None);
    sched.run_to_completion().unwrap();
    for id in [doc, chat] {
        let seq = sched.finished.iter().find(|s| s.id == id).unwrap();
        assert_eq!(seq.generated.len(), 8,
                   "request {id} was evicted instead of served: {:?}",
                   seq.state);
    }
    assert_eq!(sched.kv.stats().seqs, 0);
    assert_eq!(sched.kv.free_token_capacity(),
               sched.kv.total_token_capacity());
}

fn q8_engine<'a>(rt: &'a Runtime, cfg: &str, seed: u64) -> Engine<'a> {
    let params = ParamStore::init(rt.manifest().config(cfg).unwrap(), 42);
    Engine::with_kv_quant(rt, cfg, params, false, Sampler::Greedy, seed,
                          KvQuant::Q8)
        .unwrap()
}

/// Max abs difference between the two engines' last decode logits over
/// the LIVE lanes only (hole lanes decode stale dummy rows — bounded too,
/// but not part of the contract).
fn live_logit_err(e32: &Engine, e8: &Engine, live: &[u64], vocab: usize)
    -> f64 {
    let l32 = &e32.last_decode_logits().expect("fp32 logits").data;
    let l8 = &e8.last_decode_logits().expect("q8 logits").data;
    let mut worst = 0f64;
    for &id in live {
        let lane = e32.lane_of(id).expect("live lane");
        assert_eq!(e8.lane_of(id), Some(lane),
                   "engines disagree on lane of {id}");
        for i in lane * vocab..(lane + 1) * vocab {
            worst = worst.max((l32[i] - l8[i]).abs() as f64);
        }
    }
    worst
}

/// Shared q8 churn-parity scenario (ISSUE 4, reused by the grouped
/// configs in ISSUE 5): the q8 engine, teacher-forced to follow the fp32
/// engine's tokens through monolithic AND chunked prefill, tier growth,
/// retirement churn, a mid-flight join, and tier shrink, must keep its
/// decode logits within a tight absolute bound of the fp32 engine's —
/// while moving exactly 4x fewer arena payload bytes and never
/// downloading a full arena. Returns the final (fp32, q8) metrics and
/// the final (bucket, tier) of the last (chunked) run so callers can
/// assert config-specific arena geometry on top.
fn q8_churn_parity(rt: &Runtime, cfg_name: &str)
    -> (thinkeys::coordinator::metrics::EngineMetrics,
        thinkeys::coordinator::metrics::EngineMetrics,
        usize, usize) {
    let mut last = None;
    for chunked in [false, true] {
        let cfg = rt.manifest().config(cfg_name).unwrap().clone();
        let mut e32 = engine(rt, cfg_name, 0);
        let mut e8 = q8_engine(rt, cfg_name, 0);
        let mut rng = Rng::new(29);
        let p_doc = synth_prompt(90, cfg.vocab, &mut rng);   // forces n=128
        let p_chat = synth_prompt(10, cfg.vocab, &mut rng);
        let p_join = synth_prompt(9, cfg.vocab, &mut rng);
        let mk = |p: &Vec<i32>, id: u64| Sequence::new(id, p.clone(), 64, None);
        let (mut d32, mut c32, mut j32) =
            (mk(&p_doc, 1), mk(&p_chat, 2), mk(&p_join, 3));
        let (mut d8, mut c8, mut j8) =
            (mk(&p_doc, 1), mk(&p_chat, 2), mk(&p_join, 3));
        // fp32 engine always prefills monolithically (the reference);
        // the q8 engine alternates: monolithic (host-side quantization
        // on park) and chunked (device-side quantize-on-write) — both
        // must live inside the same bound
        e32.prefill(&mut d32).unwrap();
        e32.prefill(&mut c32).unwrap();
        if chunked {
            let chunk = *rt.manifest().chunks_for(cfg_name).first()
                .unwrap();
            while !e8.prefill_chunk(&mut d8, chunk).unwrap() {}
            while !e8.prefill_chunk(&mut c8, chunk).unwrap() {}
        } else {
            e8.prefill(&mut d8).unwrap();
            e8.prefill(&mut c8).unwrap();
        }
        fn force(a: &Sequence, b: &mut Sequence) {
            *b.generated.last_mut().unwrap() = *a.generated.last().unwrap();
        }
        /// One lockstep decode: both engines step the same live set, the
        /// live lanes' logits are compared, and the q8 engine is
        /// teacher-forced onto the fp32 tokens.
        fn step_both(e32: &mut Engine, e8: &mut Engine,
                     s32: &mut [&mut Sequence], s8: &mut [&mut Sequence],
                     vocab: usize) -> f64 {
            let live: Vec<u64> = s32.iter().map(|s| s.id).collect();
            e32.decode_step(s32).unwrap();
            e8.decode_step(s8).unwrap();
            let err = live_logit_err(e32, e8, &live, vocab);
            for (a, b) in s32.iter().zip(s8.iter_mut()) {
                force(a, b);
            }
            err
        }
        force(&d32, &mut d8);
        force(&c32, &mut c8);
        let mut worst = 0f64;
        // phase 1: doc + chat decode together at tier 128
        for _ in 0..4 {
            let err = step_both(&mut e32, &mut e8,
                                &mut [&mut d32, &mut c32],
                                &mut [&mut d8, &mut c8], cfg.vocab);
            worst = worst.max(err);
        }
        assert_eq!(e32.current_tier(), 128);
        assert_eq!(e8.current_tier(), 128);
        // phase 2: the doc retires (zero-copy hole) — churn
        e32.drop_seq(1);
        e8.drop_seq(1);
        for _ in 0..6 {
            let err = step_both(&mut e32, &mut e8,
                                &mut [&mut c32], &mut [&mut c8], cfg.vocab);
            worst = worst.max(err);
        }
        // the arena shrank after the doc left (both engines, same tier)
        assert!(e32.current_tier() < 128, "fp32 tier stuck");
        assert_eq!(e8.current_tier(), e32.current_tier(), "tier diverged");
        // phase 3: a joiner unparks into the hole — join + repack
        e32.prefill(&mut j32).unwrap();
        if chunked {
            let chunk = *rt.manifest().chunks_for(cfg_name).first()
                .unwrap();
            while !e8.prefill_chunk(&mut j8, chunk).unwrap() {}
        } else {
            e8.prefill(&mut j8).unwrap();
        }
        force(&j32, &mut j8);
        for _ in 0..20 {
            let err = step_both(&mut e32, &mut e8,
                                &mut [&mut c32, &mut j32],
                                &mut [&mut c8, &mut j8], cfg.vocab);
            worst = worst.max(err);
        }
        // the chat grew back across a tier boundary mid-run (10 prompt +
        // 30 generated = 40 rows > 32)
        assert!(e8.metrics.tier_switches >= 2,
                "q8 run saw no grow+shrink churn");
        assert!(worst.is_finite() && worst > 0.0 && worst < 0.05,
                "q8 logit error out of bounds (chunked={chunked}): {worst}");
        // sync contract holds in q8: zero full-arena downloads
        assert_eq!(e8.metrics.sync_download_bytes, 0);
        // exact 4x payload at matched (bucket, tier); scales visible
        assert_eq!(e32.metrics.arena_bytes, 4 * e8.metrics.arena_bytes);
        assert_eq!(e32.metrics.arena_k_bytes, 4 * e8.metrics.arena_k_bytes);
        assert!(e8.metrics.arena_scale_bytes > 0);
        assert_eq!(e32.metrics.arena_scale_bytes, 0);
        // per-step delta sync also shrank (codes + scales < fp32 rows);
        // only comparable when both engines prefilled monolithically —
        // the chunked q8 run additionally charges its chunk deltas to
        // row_sync_bytes, which the monolithic fp32 reference never pays
        if !chunked {
            assert!(e8.metrics.row_sync_bytes < e32.metrics.row_sync_bytes);
        }
        last = Some((e32.metrics.clone(), e8.metrics.clone(),
                     e8.current_bucket(), e8.current_tier()));
    }
    last.expect("churn scenario ran")
}

/// THE q8 parity acceptance (ISSUE 4) on the factored MHA config.
/// Measured worst-case error with init params is ~2e-3; 0.05 is ~25x
/// headroom and still catches any real dequant/scale/scatter defect.
#[test]
fn q8_decode_parity_bounded_under_churn() {
    let rt = runtime();
    q8_churn_parity(&rt, "servethin");
}

/// THE composed gqa × q8 acceptance (ISSUE 5): the grouped configs run
/// the same churn scenario (monolithic + chunked prefill × tier
/// grow/shrink × retirement × join) with the parity bound and the
/// `sync_download_bytes == 0` tripwire intact, AND the measured arena
/// gauges must equal the grouped-width arenas exactly — `k_cache_dims =
/// n_kv_heads · d_qk_head`, never a query-head width — so the exact
/// composed ratio vs the servefull-fp32 geometry (16x grouped-full, 64x
/// grouped-thin at q8 element width) holds byte-for-byte.
#[test]
fn gqa_q8_decode_parity_bounded_under_churn() {
    let rt = runtime();
    let full = rt.manifest().config("servefull").unwrap().clone();
    for cfg_name in ["servegqa", "servegqathin"] {
        let cfg = rt.manifest().config(cfg_name).unwrap().clone();
        assert!(cfg.n_kv_heads < cfg.n_heads, "{cfg_name} not grouped");
        assert_eq!(cfg.k_cache_dims, cfg.n_kv_heads * cfg.d_qk_head);
        let (m32, m8, bucket, tier) = q8_churn_parity(&rt, cfg_name);
        let l = cfg.n_layers;
        // the q8 K arena is exactly the grouped-width int8 arena ...
        assert_eq!(m8.arena_k_bytes as usize,
                   l * bucket * tier * cfg.k_cache_dims,
                   "{cfg_name}: K arena not sized by KV heads");
        // ... the fp32 twin exactly 4 bytes/element over the same dims
        assert_eq!(m32.arena_k_bytes as usize,
                   l * bucket * tier * cfg.k_cache_dims * 4);
        // exact composed grouped ratio vs servefull-fp32 at the same
        // (bucket, tier): fp32 full width over q8 grouped width
        let ratio = (full.k_cache_dims * 4 / cfg.k_cache_dims) as u64;
        assert_eq!(ratio,
                   if cfg_name == "servegqathin" { 64 } else { 16 });
        assert_eq!((l * bucket * tier * full.k_cache_dims * 4) as u64,
                   ratio * m8.arena_k_bytes,
                   "{cfg_name}: composed grouped ratio off");
        // one fp32 scale per K row — the honest overhead, visible
        assert_eq!(m8.arena_k_scale_bytes as usize, l * bucket * tier * 4);
    }
}

/// q8 serving end to end through the scheduler/router stack: the mixed
/// closed loop completes, accounting balances, and the download tripwire
/// holds — the quantized engine is a drop-in behind the same coordinator.
#[test]
fn q8_router_closed_loop_end_to_end() {
    let rt = runtime();
    let eng = q8_engine(&rt, "servethin", 7);
    let kv = kv_for(&rt, "servethin", 4.0);
    let sched = Scheduler::new(eng, kv, 8);
    let mut router = Router::new(sched);
    let trace = closed_loop(12, 24, 8);
    let report = router.run_closed_loop(&trace, 0).unwrap();
    assert_eq!(report.n_requests, 12);
    assert_eq!(report.rejected, 0);
    assert_eq!(report.gen_tokens, 12 * 8);
    let m = &router.sched.engine.metrics;
    assert_eq!(m.sync_download_bytes, 0,
               "q8 full-arena download regression");
    assert!(m.arena_scale_bytes > 0);
    assert_eq!(router.sched.kv.stats().seqs, 0);
}

/// q8 chunked prefill parks the same rows whatever chunk size produced
/// them (row codes depend only on the quantized prefix, not on chunk
/// boundaries), and generation afterwards is identical per chunk size.
#[test]
fn q8_chunked_prefill_schedule_independent() {
    let rt = runtime();
    let cfg = rt.manifest().config("servethin").unwrap().clone();
    let chunks = rt.manifest().chunks_for("servethin");
    let mut rng = Rng::new(41);
    let prompt = synth_prompt(37, cfg.vocab, &mut rng);
    let mut reference: Option<(usize, Vec<f32>, Vec<f32>, Vec<i32>)> = None;
    for &c in &chunks {
        let mut eng = q8_engine(&rt, "servethin", 0);
        let mut seq = Sequence::new(1, prompt.clone(), 6, None);
        while !eng.prefill_chunk(&mut seq, c).unwrap() {}
        let snap = eng.parked_snapshot(1).unwrap();
        while !seq.is_finished() {
            let mut seqs = vec![&mut seq];
            eng.decode_step(&mut seqs).unwrap();
        }
        match &reference {
            None => reference = Some((snap.0, snap.1, snap.2,
                                      seq.generated.clone())),
            Some((len, k, v, gen)) => {
                assert_eq!(snap.0, *len, "c={c}");
                assert!(snap.1 == *k && snap.2 == *v,
                        "c={c}: q8 parked rows depend on chunk schedule");
                assert_eq!(&seq.generated, gen,
                           "c={c}: generation depends on chunk schedule");
            }
        }
    }
}

/// A failed prefill must roll back its KV reservation (no leak) and fail
/// the request visibly instead of vanishing half-admitted.
#[test]
fn prefill_failure_releases_reservation() {
    let rt = runtime();
    let eng = engine(&rt, "servethin", 3);
    let too_long = eng.max_prompt() + 1;
    let kv = kv_for(&rt, "servethin", 16.0);
    let mut sched = Scheduler::new(eng, kv, 8);
    let cap0 = sched.kv.free_token_capacity();
    let vocab = sched.engine.cfg.vocab;
    let mut rng = Rng::new(2);
    sched.submit(synth_prompt(too_long, vocab, &mut rng), 4, None);
    sched.step().unwrap();
    assert_eq!(sched.n_running(), 0);
    assert_eq!(sched.n_waiting(), 0);
    assert_eq!(sched.finished.len(), 1);
    assert_eq!(sched.finished[0].state,
               SeqState::Finished(FinishReason::PrefillFailed));
    assert_eq!(sched.kv.free_token_capacity(), cap0,
               "prefill failure leaked KV blocks");
    assert_eq!(sched.kv.stats().seqs, 0);
}

/// Preemption restarts TTFT: the recorded first-token time must reflect
/// the admission that actually served the request, not the first one.
#[test]
fn preemption_resets_ttft() {
    let rt = runtime();
    let eng = engine(&rt, "servethin", 5);
    let kv = kv_for(&rt, "servethin", 16.0);
    let mut sched = Scheduler::new(eng, kv, 8);
    let vocab = sched.engine.cfg.vocab;
    let mut rng = Rng::new(6);
    let id = sched.submit(synth_prompt(8, vocab, &mut rng), 5, None);
    sched.step().unwrap(); // admit + prefill + one decode token
    assert_eq!(sched.n_running(), 1);
    let t_preempt = std::time::Instant::now();
    assert_eq!(sched.preempt_one(), Some(id));
    assert_eq!(sched.n_running(), 0);
    assert_eq!(sched.n_waiting(), 1);
    assert_eq!(sched.kv.stats().seqs, 0, "preemption must release blocks");
    sched.run_to_completion().unwrap();
    assert_eq!(sched.finished.len(), 1);
    let seq = &sched.finished[0];
    assert_eq!(seq.generated.len(), 5);
    assert!(seq.first_token_at.unwrap() >= t_preempt,
            "TTFT measured against the pre-preemption admission");
}

/// Admission control: an over-budget burst is partially admitted, the rest
/// completes as capacity frees up — nothing deadlocks, accounting is exact.
#[test]
fn admission_under_pressure() {
    let rt = runtime();
    let eng = engine(&rt, "servefull", 11);
    // tiny budget: ~3 concurrent sequences of (24 prompt + 8 gen + pad)
    let kv = kv_for(&rt, "servefull", 0.12);
    let sched = Scheduler::new(eng, kv, 8);
    let mut router = Router::new(sched);
    let trace = closed_loop(6, 24, 8);
    let report = router.run_closed_loop(&trace, 0).unwrap();
    assert_eq!(report.n_requests, 6);
    assert_eq!(report.gen_tokens, 6 * 8);
    assert_eq!(router.sched.kv.stats().seqs, 0);
}

/// ISSUE 6: the runtime invariant auditor rides along every scheduler
/// round (debug builds and `--features audit` release builds) through a
/// full churn workload — chunked prefill, bucket regroups, retirements —
/// and never fires. A single violation fails `step()`, so completing the
/// workload IS the assertion; the gated counter check proves the auditor
/// actually ran rather than being compiled out.
#[test]
fn auditor_active_through_churn() {
    let rt = runtime();
    let cfg = rt.manifest().config("servethin").unwrap().clone();
    let chunk = rt.manifest().chunks_for("servethin").first().copied();
    let eng = engine(&rt, "servethin", 5);
    let kv = kv_for(&rt, "servethin", 4.0);
    let mut sched = Scheduler::with_config(eng, kv, SchedConfig {
        max_batch: 6,
        round_budget: 64,
        chunk_tokens: chunk,
        interactive_weight: 4,
        ..SchedConfig::default()
    });
    let mut rng = Rng::new(33);
    // staggered submissions so the live set grows, shrinks, and regroups
    for i in 0..10 {
        let len = 6 + rng.below(20);
        let p = synth_prompt(len, cfg.vocab, &mut rng);
        sched.submit_seq(p, 4 + (i % 5), None, Priority::Interactive, None);
        sched.step().unwrap();
    }
    sched.run_to_completion().unwrap();
    assert_eq!(sched.finished.len(), 10);
    assert_eq!(sched.kv.stats().seqs, 0, "cache not fully released");
    let m = &sched.engine.metrics;
    assert_eq!(m.sync_download_bytes, 0,
               "serving must keep the KV cache device-resident");
    #[cfg(any(debug_assertions, feature = "audit"))]
    assert!(m.audit_checks > 0,
            "auditor was enabled but never cross-checked a round");
    #[cfg(not(any(debug_assertions, feature = "audit")))]
    assert_eq!(m.audit_checks, 0,
               "plain release builds must not pay for the audit");
}

/// The auditor must actually catch divergence, not just bless healthy
/// state: a KV table holding committed rows for a sequence the engine
/// does not track is the classic leak after a mis-paired release, and
/// `analysis::auditor::audit` must name it.
#[test]
fn auditor_catches_leaked_kv_table() {
    let rt = runtime();
    let eng = engine(&rt, "servethin", 3);
    let mut kv = kv_for(&rt, "servethin", 4.0);
    assert!(thinkeys::analysis::auditor::audit(&eng, &kv).is_empty(),
            "fresh engine + empty cache must audit clean");
    // seed the corruption: a table with committed rows, unknown to the
    // engine
    kv.allocate(99, 32).unwrap();
    kv.commit_rows(99, 8).unwrap();
    let violations = thinkeys::analysis::auditor::audit(&eng, &kv);
    assert!(violations.iter().any(|v| v.contains("no longer tracks")),
            "auditor missed the leaked table: {violations:?}");
}

/// ISSUE 8 acceptance, end to end: N chat users over ONE system prompt
/// prefill the shared prefix exactly once (computed prefill tokens ==
/// unique tokens, `prefix_hits == N-1`), hold strictly more concurrent
/// sequences AND strictly lower interactive TTFT p50 than the per-lane
/// baseline on the SAME block budget, decode bit-exactly the
/// sharing-disabled outputs, and keep the auditor green with zero
/// full-arena downloads throughout.
#[test]
fn shared_prefix_cohort_meets_the_acceptance_bar() {
    let rt = runtime();
    let (users, system, user, gen, pool) = (6usize, 48, 8, 6, 12);
    let shared = thinkeys::experiments::serving::shared_prefix_run(
        &rt, "servethin", users, system, user, gen, pool, true).unwrap();
    let unshared = thinkeys::experiments::serving::shared_prefix_run(
        &rt, "servethin", users, system, user, gen, pool, false).unwrap();

    // everyone is served in both modes — sharing is a capacity win, the
    // baseline just queues longer
    assert_eq!(shared.report.n_requests, users);
    assert_eq!(unshared.report.n_requests, users);
    assert_eq!(shared.report.rejected, 0);
    assert_eq!(unshared.report.rejected, 0);

    // the shared prefix is computed exactly once: prefill token count
    // equals the cohort's UNIQUE tokens, and every user after the first
    // adopts it
    assert_eq!(shared.prefill_tokens, (system + users * user) as u64,
               "shared run recomputed part of the shared prefix");
    assert_eq!(shared.prefix_hits, users as u64 - 1);
    assert_eq!(shared.prefix_hit_tokens, ((users - 1) * system) as u64);
    assert_eq!(unshared.prefill_tokens, (users * (system + user)) as u64);
    assert_eq!(unshared.prefix_hits, 0);

    // capacity: strictly more users live at once on the identical pool,
    // with real deduplication while they are
    assert!(shared.peak_concurrent > unshared.peak_concurrent,
            "sharing held {} concurrent vs baseline {}",
            shared.peak_concurrent, unshared.peak_concurrent);
    assert!(shared.peak_dedup_bytes > 0.0 && shared.peak_shared_blocks > 0);
    assert_eq!(unshared.peak_dedup_bytes, 0.0);

    // interactive latency: the median user stops paying for the queue
    let (p50_s, p50_u) = (shared.report.ttft.quantile_us(0.5),
                          unshared.report.ttft.quantile_us(0.5));
    assert!(p50_s < p50_u,
            "TTFT p50 did not improve: {p50_s:.0}us vs {p50_u:.0}us");

    // outputs are bit-exact across sharing modes
    assert_eq!(shared.outputs, unshared.outputs,
               "prefix sharing changed decoded tokens");
    assert_eq!(shared.outputs.len(), users);
    assert!(shared.outputs.iter().all(|o| o.len() == gen));

    // auditor green, KV device-resident, in both modes
    assert_eq!(shared.sync_download_bytes, 0);
    assert_eq!(unshared.sync_download_bytes, 0);
    #[cfg(any(debug_assertions, feature = "audit"))]
    {
        assert!(shared.audit_checks > 0 && unshared.audit_checks > 0,
                "auditor never cross-checked a round");
    }
}

/// Copy-on-write divergence: forking a sequence with a partial tail
/// block privately copies the tail (one `cow_split`), the child decodes
/// on from the parent's history, and both finish with histories that
/// agree up to the fork — greedy continuations of the same prefix.
#[test]
fn fork_splits_the_partial_tail_and_diverges_privately() {
    let rt = runtime();
    let eng = engine(&rt, "servethin", 0);
    let kv = kv_for(&rt, "servethin", 4.0);
    let mut sched = Scheduler::with_config(eng, kv, SchedConfig {
        max_batch: 4,
        ..SchedConfig::default()
    });
    let cfg = rt.manifest().config("servethin").unwrap().clone();
    let mut rng = Rng::new(5);
    // 20-token prompt: one full block plus a partial tail to CoW-split
    let parent = sched.submit(synth_prompt(20, cfg.vocab, &mut rng), 8, None);
    sched.step().unwrap();
    sched.step().unwrap();
    let child = sched.fork(parent, 4).unwrap();
    assert_eq!(sched.engine.metrics.cow_splits, 1,
               "partial tail must be privately copied on fork");
    sched.run_to_completion().unwrap();
    assert_eq!(sched.finished.len(), 2);
    let p = sched.finished.iter().find(|s| s.id == parent).unwrap();
    let c = sched.finished.iter().find(|s| s.id == child).unwrap();
    assert!(matches!(p.state, SeqState::Finished(FinishReason::MaxTokens)));
    assert!(matches!(c.state, SeqState::Finished(FinishReason::MaxTokens)));
    // same prompt, greedy sampling: the shorter history is a prefix of
    // the longer — the fork shared blocks without sharing FUTURE writes
    let n = p.generated.len().min(c.generated.len());
    assert_eq!(&p.generated[..n], &c.generated[..n],
               "fork corrupted the shared history");
    // the drained pool holds nothing: fork's refcounts fully unwound
    assert_eq!(sched.kv.sharing_stats().blocks_used, 0);
    assert!(sched.kv.refcount_violations().is_empty());
}
