//! Property: the exported artifact grid covers everything the scheduler's
//! hysteresis state machines can actually visit (ISSUE 6 satellite).
//!
//! Two layers, both seeded via `thinkeys::proptest::property`:
//!
//! 1. Synthetic (always runs, no artifacts): random pow2 ladders + random
//!    grow/shrink churn through the *real* `lanes::target_bucket` /
//!    `lanes::target_tier` functions; every state visited must be inside
//!    the closure computed by `grid::reachable_buckets` /
//!    `grid::reachable_tiers`. This pins the static auditor's reachability
//!    model to the live state machines — if someone changes the hysteresis
//!    rule without updating the checker (or vice versa), this fails.
//! 2. Manifest-backed (needs `make artifacts`): random admission, decode
//!    growth, bucket regroup, and retirement sequences against each
//!    exported serving config; every (bucket, tier, kv_quant) cell the
//!    churn reaches must resolve to an artifact in the manifest.

use std::collections::BTreeSet;

use thinkeys::analysis::grid;
use thinkeys::coordinator::lanes;
use thinkeys::proptest::property;
use thinkeys::runtime::Manifest;

/// Random ascending pow2 ladder, e.g. [32, 64, 256].
fn random_ladder(rng: &mut thinkeys::substrate::rng::Rng) -> Vec<usize> {
    let lo = 4 + rng.below(4); // 2^4..2^7 start
    let len = 1 + rng.below(4);
    let mut out = Vec::new();
    let mut exp = lo;
    for _ in 0..len {
        out.push(1usize << exp);
        exp += 1 + rng.below(2);
    }
    out
}

#[test]
fn hysteresis_never_escapes_reachable_tier_closure() {
    property("tier_closure", 300, |rng| {
        let tiers = random_ladder(rng);
        let max_seq = *tiers.last().expect("ladder non-empty");
        let reach = grid::reachable_tiers(&tiers, max_seq)
            .map_err(|e| format!("closure: {e}"))?;
        let mut current = 0usize;
        let mut need = 1usize;
        for _ in 0..60 {
            match rng.below(3) {
                0 => need = (need + 1 + rng.below(32)).min(max_seq),
                1 => need = need.saturating_sub(1 + rng.below(64)).max(1),
                _ => {}
            }
            let next = lanes::target_tier(&tiers, need, current)
                .ok_or_else(|| format!("no tier for need={need}"))?;
            if !reach.contains(&next) {
                return Err(format!(
                    "tier {next} (need={need}, from {current}, ladder \
                     {tiers:?}) is outside the reachable closure {reach:?}"
                ));
            }
            current = next;
        }
        Ok(())
    });
}

#[test]
fn regroup_never_escapes_reachable_bucket_closure() {
    property("bucket_closure", 300, |rng| {
        let buckets = random_ladder(rng)
            .iter()
            .map(|b| b >> 3) // 2..16-ish lane counts
            .filter(|&b| b >= 1)
            .collect::<Vec<_>>();
        if buckets.is_empty() {
            return Ok(());
        }
        let max = *buckets.last().expect("non-empty");
        let reach = grid::reachable_buckets(&buckets)
            .map_err(|e| format!("closure: {e}"))?;
        let mut current = 0usize;
        let mut n = 1usize;
        for _ in 0..60 {
            match rng.below(2) {
                0 => n = (n + 1 + rng.below(4)).min(max),
                _ => n = n.saturating_sub(1 + rng.below(4)).max(1),
            }
            let next = lanes::target_bucket(&buckets, n, current)
                .ok_or_else(|| format!("no bucket for n={n}"))?;
            if !reach.contains(&next) {
                return Err(format!(
                    "bucket {next} (n={n}, from {current}, ladder \
                     {buckets:?}) is outside the closure {reach:?}"
                ));
            }
            current = next;
        }
        Ok(())
    });
}

#[test]
fn churn_only_visits_cells_the_manifest_exports() {
    let m = match Manifest::load(&thinkeys::artifacts_dir()) {
        Ok(m) => m,
        Err(_) => {
            eprintln!(
                "grid_reachability: no artifact grid (run `make artifacts`); \
                 manifest-backed property skipped"
            );
            return;
        }
    };
    let configs: Vec<String> = m
        .decode_tiers
        .keys()
        .filter(|c| m.configs.contains_key(*c))
        .cloned()
        .collect();
    assert!(
        !configs.is_empty(),
        "manifest exports no tiered serving configs"
    );
    property("grid_covers_churn", 150, |rng| {
        let name = &configs[rng.below(configs.len())];
        let cfg = m.config(name).map_err(|e| e.to_string())?;
        let tiers = m.tiers_for(name);
        let buckets = m.decode_batches.clone();
        let quants = m.kv_quants_for(name);
        let max_batch = *buckets.last().expect("decode_batches non-empty");

        // Live-set churn: admissions bump n, retirements drop it; decode
        // steps grow the longest context, retirement of the longest
        // sequence can shrink it. Bucket and tier follow the real
        // hysteresis functions, exactly as Engine::regroup does.
        let mut bucket = 0usize;
        let mut tier = 0usize;
        let mut n = 0usize;
        let mut need = 0usize;
        let mut visited: BTreeSet<(usize, usize)> = BTreeSet::new();
        for _ in 0..80 {
            match rng.below(4) {
                // admit a batch of requests with fresh prompts
                0 => {
                    let k = 1 + rng.below(4);
                    n = (n + k).min(max_batch);
                    need = need.max(1 + rng.below(cfg.max_seq / 2));
                }
                // decode rounds: every live sequence grows one row
                1 | 2 => {
                    if n > 0 {
                        need = (need + 1 + rng.below(8)).min(cfg.max_seq);
                    }
                }
                // retire: drop sequences; longest context may shrink
                _ => {
                    let k = 1 + rng.below(4);
                    n = n.saturating_sub(k);
                    if n == 0 {
                        need = 0;
                    } else if rng.below(2) == 0 {
                        need = 1 + rng.below(need.max(1));
                    }
                }
            }
            if n == 0 {
                continue;
            }
            bucket = lanes::target_bucket(&buckets, n, bucket)
                .ok_or_else(|| format!("no bucket fits n={n}"))?;
            tier = lanes::target_tier(&tiers, need.max(1), tier)
                .ok_or_else(|| format!("no tier fits need={need}"))?;
            visited.insert((bucket, tier));
            for &q in &quants {
                let artifact = m.decode_name(name, bucket, tier, false, q);
                if !m.artifacts.contains_key(&artifact) {
                    return Err(format!(
                        "{name}: churn reached (b={bucket}, n={tier}, \
                         {}) but the grid has no {artifact}",
                        q.name()
                    ));
                }
            }
        }
        if visited.is_empty() {
            return Err("churn never produced a live state".into());
        }
        Ok(())
    });
}
