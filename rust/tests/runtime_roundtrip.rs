//! Integration: the python-AOT → rust-PJRT bridge works end to end —
//! train steps reduce loss, eval PPL is sane, QK-FT touches only QK,
//! and the factored-keys equivalence holds through real executables.

use thinkeys::datagen::{copyback, corpus::{Corpus, CorpusModel}};
use thinkeys::model::surgery::{self, AblationMode};
use thinkeys::runtime::client::{tensor_to_literal, Arg};
use thinkeys::runtime::{KvQuant, ParamStore, Runtime};
use thinkeys::substrate::rng::Rng;
use thinkeys::substrate::tensor::{Tensor, TensorI32, TensorI8};
use thinkeys::train::{eval, Schedule, Trainer, TrainState};

fn runtime() -> Runtime {
    Runtime::new().expect("run `make artifacts` before cargo test")
}

#[test]
fn train_step_memorizes_fixed_batch() {
    // Overfit one batch: the optimizer path must drive loss well below the
    // uniform baseline ln(16)=2.77 within a few dozen steps.
    let rt = runtime();
    let trainer = Trainer::new(&rt, "copyback_ds16", false).unwrap();
    let (b, s) = (trainer.cfg.train_batch, trainer.cfg.train_seq);
    let mut st = TrainState::new(&trainer.cfg, 0);
    let mut rng = Rng::new(1);
    let fixed = copyback::batch(b, s, &mut rng);
    let sched = Schedule::Constant { lr: 3e-3 };
    let out = trainer.run(&mut st, 60, &sched, |_| fixed.clone()).unwrap();
    let first = out.losses[0];
    let last = out.final_loss();
    assert!(last < 1.5, "failed to memorize: {first} -> {last}");
    assert_eq!(st.step, 60);
}

#[test]
fn eval_ppl_of_random_model_is_near_vocab() {
    // An untrained model's PPL should be ~vocab (uniform predictions).
    let rt = runtime();
    let cfg = rt.manifest().config("tinylm_ds32").unwrap().clone();
    let params = ParamStore::init(&cfg, 0);
    let model = CorpusModel::new(7, cfg.vocab);
    let corpus = Corpus::generate(&model, 30_000, 0);
    let batches = corpus.batches(&corpus.val, cfg.train_batch, cfg.train_seq, 0);
    let ppl = eval::eval_ppl(&rt, &cfg, &params, &batches[..4]).unwrap();
    assert!(
        ppl > 0.25 * cfg.vocab as f64 && ppl < 4.0 * cfg.vocab as f64,
        "untrained ppl {ppl}"
    );
}

#[test]
fn qkft_updates_only_qk_params() {
    let rt = runtime();
    let trainer = Trainer::new(&rt, "tinylm_ds32", true).unwrap();
    let mut st = TrainState::new(&trainer.cfg, 0);
    let before = st.params.clone();
    let model = CorpusModel::new(7, trainer.cfg.vocab);
    let corpus = Corpus::generate(&model, 10_000, 0);
    let batches =
        corpus.batches(&corpus.train, trainer.cfg.train_batch,
                       trainer.cfg.train_seq, 0);
    trainer.step(&mut st, &batches[0], 1e-3).unwrap();
    for (i, spec) in trainer.cfg.params.iter().enumerate() {
        let changed =
            before.tensors[i].max_abs_diff(&st.params.tensors[i]) > 0.0;
        assert_eq!(changed, spec.qk, "{}", spec.name);
    }
}

#[test]
fn factored_model_matches_reconstructed_model_ppl() {
    // The paper's deployment claim: K-only low-rank reconstruction PPL
    // (same shapes as original) equals the thin deployment PPL (surgeried
    // weights on the thin artifact family) — here through real HLO.
    let rt = runtime();
    let m = rt.manifest();
    let full_cfg = m.config("tinylm_ds64").unwrap().clone();
    let thin_cfg = m.config("tinylm_ds32").unwrap().clone();
    let full = ParamStore::init(&full_cfg, 11);
    let model = CorpusModel::new(7, full_cfg.vocab);
    let corpus = Corpus::generate(&model, 20_000, 0);
    let batches =
        corpus.batches(&corpus.val, full_cfg.train_batch, full_cfg.train_seq, 0);
    let eval_batches = &batches[..2];

    let recon = surgery::low_rank_ablation(
        &full, &full_cfg, thin_cfg.d_qk_head, AblationMode::KOnly).unwrap();
    let thin = surgery::factor_to_thin(&full, &full_cfg, &thin_cfg).unwrap();

    let ppl_recon =
        eval::eval_ppl(&rt, &full_cfg, &recon, eval_batches).unwrap();
    let ppl_thin =
        eval::eval_ppl(&rt, &thin_cfg, &thin, eval_batches).unwrap();
    let rel = (ppl_recon - ppl_thin).abs() / ppl_recon;
    assert!(
        rel < 1e-3,
        "deployment mismatch: recon {ppl_recon} vs thin {ppl_thin}"
    );
}

#[test]
fn logits_artifact_shape_and_finiteness() {
    let rt = runtime();
    let cfg = rt.manifest().config("copyback_ds4").unwrap().clone();
    let params = ParamStore::init(&cfg, 0);
    let mut rng = Rng::new(0);
    let batch = copyback::batch(cfg.train_batch, cfg.train_seq, &mut rng);
    let logits = eval::logits_for(&rt, &cfg, &params, &batch).unwrap();
    assert_eq!(logits.shape,
               vec![cfg.train_batch, cfg.train_seq, cfg.vocab]);
    assert!(logits.data.iter().all(|x| x.is_finite()));
}

#[test]
fn wrong_arg_count_is_rejected() {
    let rt = runtime();
    let name = rt.manifest().logits_name("copyback_ds4");
    assert!(rt.execute(&name, &[]).is_err());
}

/// The dtype fail-fast satellite (ISSUE 4): a stale fp32 cache literal —
/// or an fp32 tensor — fed where a q8 artifact expects an int8 arena must
/// be rejected by `Runtime::execute`'s manifest validation, never
/// silently reinterpreted by XLA. Both the `Arg::F` and the cached
/// `Arg::L` lanes are covered; the correctly-typed i8 call assembles past
/// validation.
#[test]
fn q8_artifact_rejects_fp32_cache_args() {
    let rt = runtime();
    let m = rt.manifest();
    let cfg = m.config("servethin").unwrap().clone();
    let tier = *m.tiers_for("servethin").first().unwrap();
    let name = m.decode_name("servethin", 1, tier, false, KvQuant::Q8);
    let entry = m.artifact(&name).unwrap();
    let (l, kd, vd) = (cfg.n_layers, cfg.k_cache_dims, cfg.v_cache_dims);
    let params = ParamStore::init(&cfg, 0);

    // correctly-typed args (the last two elements are tokens/pos)
    let k_q = TensorI8::zeros(&[l, 1, tier, kd]);
    let k_s = Tensor::zeros(&[l, 1, tier]);
    let v_q = TensorI8::zeros(&[l, 1, tier, vd]);
    let v_s = Tensor::zeros(&[l, 1, tier]);
    let toks = TensorI32::new(&[1], vec![3]);
    let pos = TensorI32::new(&[1], vec![0]);
    let k_f32 = Tensor::zeros(&[l, 1, tier, kd]);
    let stale = tensor_to_literal(&k_f32).unwrap();

    fn q8_args<'a>(params: &'a ParamStore, k_cache: Arg<'a>,
                   k_s: &'a Tensor, v_q: &'a TensorI8, v_s: &'a Tensor,
                   toks: &'a TensorI32, pos: &'a TensorI32) -> Vec<Arg<'a>> {
        let mut args: Vec<Arg<'a>> =
            params.tensors.iter().map(Arg::F).collect();
        args.push(k_cache);
        args.push(Arg::F(k_s));
        args.push(Arg::I8(v_q));
        args.push(Arg::F(v_s));
        args.push(Arg::I(toks));
        args.push(Arg::I(pos));
        args
    }

    // 1) an fp32 TENSOR in the int8 slot: rejected with a dtype message
    let args = q8_args(&params, Arg::F(&k_f32), &k_s, &v_q, &v_s, &toks, &pos);
    let err = rt
        .execute(&name, &args)
        .expect_err("fp32 tensor accepted by q8 artifact");
    assert!(format!("{err:#}").contains("dtype"), "{err:#}");

    // 2) a stale fp32 cache LITERAL (right shape, wrong element type):
    // the Arg::L validation must catch it before XLA sees it
    let args = q8_args(&params, Arg::L(&stale), &k_s, &v_q, &v_s, &toks, &pos);
    let err = rt
        .execute(&name, &args)
        .expect_err("stale fp32 literal accepted by q8 artifact");
    assert!(format!("{err:#}").contains("element type"), "{err:#}");

    // 3) the correctly-typed assembly passes validation and executes
    let args = q8_args(&params, Arg::I8(&k_q), &k_s, &v_q, &v_s, &toks, &pos);
    let outs = rt.execute(&name, &args).unwrap();
    assert_eq!(outs.len(), entry.outputs.len());
    assert_eq!(entry.outputs.len(), 9, "q8 decode output arity");
}
