//! Integration: the python-AOT → rust-PJRT bridge works end to end —
//! train steps reduce loss, eval PPL is sane, QK-FT touches only QK,
//! and the factored-keys equivalence holds through real executables.

use thinkeys::datagen::{copyback, corpus::{Corpus, CorpusModel}};
use thinkeys::model::surgery::{self, AblationMode};
use thinkeys::runtime::{ParamStore, Runtime};
use thinkeys::substrate::rng::Rng;
use thinkeys::train::{eval, Schedule, Trainer, TrainState};

fn runtime() -> Runtime {
    Runtime::new().expect("run `make artifacts` before cargo test")
}

#[test]
fn train_step_memorizes_fixed_batch() {
    // Overfit one batch: the optimizer path must drive loss well below the
    // uniform baseline ln(16)=2.77 within a few dozen steps.
    let rt = runtime();
    let trainer = Trainer::new(&rt, "copyback_ds16", false).unwrap();
    let (b, s) = (trainer.cfg.train_batch, trainer.cfg.train_seq);
    let mut st = TrainState::new(&trainer.cfg, 0);
    let mut rng = Rng::new(1);
    let fixed = copyback::batch(b, s, &mut rng);
    let sched = Schedule::Constant { lr: 3e-3 };
    let out = trainer.run(&mut st, 60, &sched, |_| fixed.clone()).unwrap();
    let first = out.losses[0];
    let last = out.final_loss();
    assert!(last < 1.5, "failed to memorize: {first} -> {last}");
    assert_eq!(st.step, 60);
}

#[test]
fn eval_ppl_of_random_model_is_near_vocab() {
    // An untrained model's PPL should be ~vocab (uniform predictions).
    let rt = runtime();
    let cfg = rt.manifest().config("tinylm_ds32").unwrap().clone();
    let params = ParamStore::init(&cfg, 0);
    let model = CorpusModel::new(7, cfg.vocab);
    let corpus = Corpus::generate(&model, 30_000, 0);
    let batches = corpus.batches(&corpus.val, cfg.train_batch, cfg.train_seq, 0);
    let ppl = eval::eval_ppl(&rt, &cfg, &params, &batches[..4]).unwrap();
    assert!(
        ppl > 0.25 * cfg.vocab as f64 && ppl < 4.0 * cfg.vocab as f64,
        "untrained ppl {ppl}"
    );
}

#[test]
fn qkft_updates_only_qk_params() {
    let rt = runtime();
    let trainer = Trainer::new(&rt, "tinylm_ds32", true).unwrap();
    let mut st = TrainState::new(&trainer.cfg, 0);
    let before = st.params.clone();
    let model = CorpusModel::new(7, trainer.cfg.vocab);
    let corpus = Corpus::generate(&model, 10_000, 0);
    let batches =
        corpus.batches(&corpus.train, trainer.cfg.train_batch,
                       trainer.cfg.train_seq, 0);
    trainer.step(&mut st, &batches[0], 1e-3).unwrap();
    for (i, spec) in trainer.cfg.params.iter().enumerate() {
        let changed =
            before.tensors[i].max_abs_diff(&st.params.tensors[i]) > 0.0;
        assert_eq!(changed, spec.qk, "{}", spec.name);
    }
}

#[test]
fn factored_model_matches_reconstructed_model_ppl() {
    // The paper's deployment claim: K-only low-rank reconstruction PPL
    // (same shapes as original) equals the thin deployment PPL (surgeried
    // weights on the thin artifact family) — here through real HLO.
    let rt = runtime();
    let m = rt.manifest();
    let full_cfg = m.config("tinylm_ds64").unwrap().clone();
    let thin_cfg = m.config("tinylm_ds32").unwrap().clone();
    let full = ParamStore::init(&full_cfg, 11);
    let model = CorpusModel::new(7, full_cfg.vocab);
    let corpus = Corpus::generate(&model, 20_000, 0);
    let batches =
        corpus.batches(&corpus.val, full_cfg.train_batch, full_cfg.train_seq, 0);
    let eval_batches = &batches[..2];

    let recon = surgery::low_rank_ablation(
        &full, &full_cfg, thin_cfg.d_qk_head, AblationMode::KOnly).unwrap();
    let thin = surgery::factor_to_thin(&full, &full_cfg, &thin_cfg).unwrap();

    let ppl_recon =
        eval::eval_ppl(&rt, &full_cfg, &recon, eval_batches).unwrap();
    let ppl_thin =
        eval::eval_ppl(&rt, &thin_cfg, &thin, eval_batches).unwrap();
    let rel = (ppl_recon - ppl_thin).abs() / ppl_recon;
    assert!(
        rel < 1e-3,
        "deployment mismatch: recon {ppl_recon} vs thin {ppl_thin}"
    );
}

#[test]
fn logits_artifact_shape_and_finiteness() {
    let rt = runtime();
    let cfg = rt.manifest().config("copyback_ds4").unwrap().clone();
    let params = ParamStore::init(&cfg, 0);
    let mut rng = Rng::new(0);
    let batch = copyback::batch(cfg.train_batch, cfg.train_seq, &mut rng);
    let logits = eval::logits_for(&rt, &cfg, &params, &batch).unwrap();
    assert_eq!(logits.shape,
               vec![cfg.train_batch, cfg.train_seq, cfg.vocab]);
    assert!(logits.data.iter().all(|x| x.is_finite()));
}

#[test]
fn wrong_arg_count_is_rejected() {
    let rt = runtime();
    let name = rt.manifest().logits_name("copyback_ds4");
    assert!(rt.execute(&name, &[]).is_err());
}
