//! Paged-KV prefix-sharing properties (ISSUE 8 satellite): randomized
//! fork/join churn over the radix prefix tree.
//!
//! The contracts under test:
//! - block refcounts never leak or double-free: the per-round auditor
//!   (always on in debug test builds) stays green through arbitrary
//!   submit/fork/retire interleavings, and a drained scheduler returns
//!   every block to the pool (`blocks_used == 0`, zero refcount
//!   violations);
//! - copy-on-write never aliases a written block: every finished
//!   sequence — including forked children, whose history rides shared
//!   blocks — decodes EXACTLY the tokens a standalone single-lane run of
//!   its prompt produces (an aliased write would corrupt a neighbour's
//!   rows and break this oracle);
//! - sharing is invisible to outputs: the same randomized schedule with
//!   `prefix_sharing` disabled yields bit-identical per-sequence tokens,
//!   while the shared run computes strictly fewer prefill tokens —
//!   exactly `prefix_hit_tokens` fewer.

use std::collections::BTreeMap;

use thinkeys::coordinator::engine::Engine;
use thinkeys::coordinator::kvcache::{KvCacheConfig, KvCacheManager};
use thinkeys::coordinator::router::{bucket_of, synth_prompt, ReportBucket};
use thinkeys::coordinator::sampling::Sampler;
use thinkeys::coordinator::scheduler::{SchedConfig, Scheduler};
use thinkeys::coordinator::sequence::{SeqId, Sequence};
use thinkeys::proptest::property;
use thinkeys::runtime::{ParamStore, Runtime};
use thinkeys::substrate::rng::Rng;

fn runtime() -> Runtime {
    Runtime::new().expect("run `make artifacts` first")
}

fn engine<'a>(rt: &'a Runtime, cfg: &str) -> Engine<'a> {
    let params = ParamStore::init(rt.manifest().config(cfg).unwrap(), 42);
    Engine::new(rt, cfg, params, false, Sampler::Greedy, 0).unwrap()
}

fn kv_for(rt: &Runtime, cfg: &str, budget_bytes: f64) -> KvCacheManager {
    let c = rt.manifest().config(cfg).unwrap();
    KvCacheManager::new(KvCacheConfig {
        n_layers: c.n_layers,
        k_dims: c.k_cache_dims,
        v_dims: c.v_cache_dims,
        block_tokens: 16,
        bytes_per_el_k: 2.0,
        bytes_per_el_v: 2.0,
        budget_bytes,
    })
}

/// One pre-generated churn action. The op stream (including prompt
/// CONTENT) is fixed before either run, so the sharing-on and
/// sharing-off schedules replay identically.
#[derive(Clone, Debug)]
enum Op {
    Submit { prompt: Vec<i32>, max_new: usize },
    /// Fork the `pick % n_running`-th running sequence (skipped when
    /// nothing is running or the batch is full — identically in both
    /// modes, since admission never blocks on the ample pool).
    Fork { pick: usize, max_new: usize },
    Step,
}

/// Everything one churn run leaves behind.
struct ChurnOut {
    /// id -> (prompt, generated), COMPLETED sequences only. Ids are
    /// allocated by the scheduler in op order, so they line up across
    /// replays of the same op stream.
    done: BTreeMap<SeqId, (Vec<i32>, Vec<i32>)>,
    finished: usize,
    forked: usize,
    prefill_tokens: u64,
    prefix_hits: u64,
    prefix_hit_tokens: u64,
}

fn run_churn(rt: &Runtime, ops: &[Op], sharing: bool)
    -> Result<ChurnOut, String> {
    let eng = engine(rt, "servethin");
    let kv = kv_for(rt, "servethin", 4e6); // ample: admission never blocks
    let mut sched = Scheduler::with_config(eng, kv, SchedConfig {
        max_batch: 8,
        prefix_sharing: sharing,
        ..SchedConfig::default()
    });
    let mut forked = 0usize;
    let mut submitted = 0usize;
    for op in ops {
        match op {
            Op::Submit { prompt, max_new } => {
                sched.submit(prompt.clone(), *max_new, None);
                submitted += 1;
            }
            Op::Fork { pick, max_new } => {
                let ids = sched.running_ids();
                if !ids.is_empty()
                    && sched.fork(ids[pick % ids.len()], *max_new).is_ok()
                {
                    forked += 1;
                }
            }
            Op::Step => {}
        }
        sched.step().map_err(|e| format!("step failed: {e:#}"))?;
    }
    sched
        .run_to_completion()
        .map_err(|e| format!("drain failed: {e:#}"))?;

    // drained pool: every block back on the free list, accounting clean
    let stats = sched.kv.sharing_stats();
    if stats.blocks_used != 0 {
        return Err(format!(
            "{} blocks leaked after drain (sharing={sharing})",
            stats.blocks_used));
    }
    let v = sched.kv.refcount_violations();
    if !v.is_empty() {
        return Err(format!("refcount violations after drain: {v:?}"));
    }
    if sched.engine.metrics.sync_download_bytes != 0 {
        return Err("full-arena download during churn".into());
    }
    if sched.finished.len() != submitted + forked {
        return Err(format!(
            "{} submitted + {} forked but {} accounted for",
            submitted, forked, sched.finished.len()));
    }
    let mut done = BTreeMap::new();
    for s in &sched.finished {
        if bucket_of(s) == ReportBucket::Completed {
            done.insert(s.id, (s.prompt.clone(), s.generated.clone()));
        }
    }
    let m = &sched.engine.metrics;
    Ok(ChurnOut {
        done,
        finished: sched.finished.len(),
        forked,
        prefill_tokens: m.prefill_tokens,
        prefix_hits: m.prefix_hits,
        prefix_hit_tokens: m.prefix_hit_tokens,
    })
}

/// Randomized fork/join churn: sharing-on and sharing-off replays of one
/// op stream are bit-identical per sequence, the shared run saves
/// exactly the adopted rows, and every output matches a standalone
/// single-lane oracle (the CoW no-aliasing check).
#[test]
fn fork_join_churn_is_bitexact_and_leak_free() {
    let rt = runtime();
    property("prefix_fork_join", 3, |rng| {
        let vocab = rt.manifest().config("servethin").unwrap().vocab;
        // two prefix families, block-aligned so sealing registers them
        let families: Vec<Vec<i32>> = [16usize, 32]
            .iter()
            .map(|&n| synth_prompt(n, vocab, rng))
            .collect();
        let submit = |rng: &mut Rng, family: usize| {
            let mut p = families[family].clone();
            p.extend(synth_prompt(3 + rng.below(10), vocab, rng));
            Op::Submit { prompt: p, max_new: 2 + rng.below(4) }
        };
        // the first two ops share family 0, so every case exercises at
        // least one guaranteed prefix hit in the sharing run
        let mut ops = vec![submit(rng, 0), submit(rng, 0)];
        for _ in 0..10 {
            ops.push(match rng.below(5) {
                0 | 1 => {
                    let fam = rng.below(families.len());
                    submit(rng, fam)
                }
                2 => Op::Fork {
                    pick: rng.below(8),
                    max_new: 1 + rng.below(3),
                },
                _ => Op::Step,
            });
        }

        let shared = run_churn(&rt, &ops, true)?;
        let unshared = run_churn(&rt, &ops, false)?;

        // identical schedules, identical outcomes
        if shared.finished != unshared.finished
            || shared.forked != unshared.forked
        {
            return Err(format!(
                "schedules diverged: {}+{} vs {}+{} finished+forked",
                shared.finished, shared.forked,
                unshared.finished, unshared.forked));
        }
        if shared.done != unshared.done {
            return Err("sharing changed decoded tokens".into());
        }

        // the guaranteed family-0 repeat must have hit the tree, and the
        // shared run must have computed exactly the adopted rows fewer
        if shared.prefix_hits == 0 {
            return Err("repeated family-0 prompt never hit the tree".into());
        }
        if unshared.prefix_hits != 0 {
            return Err("sharing disabled but the tree matched".into());
        }
        if shared.prefill_tokens + shared.prefix_hit_tokens
            != unshared.prefill_tokens
        {
            return Err(format!(
                "prefill savings don't balance: {} computed + {} adopted \
                 != {} baseline",
                shared.prefill_tokens, shared.prefix_hit_tokens,
                unshared.prefill_tokens));
        }

        // CoW no-aliasing oracle: every completed sequence (forked
        // children included) must reproduce a standalone greedy run of
        // its prompt — an aliased shared block would have let one lane's
        // writes corrupt another's history
        let mut oracle = engine(&rt, "servethin");
        for (id, (prompt, generated)) in &shared.done {
            if generated.is_empty() {
                continue;
            }
            let mut s =
                Sequence::new(*id, prompt.clone(), generated.len(), None);
            oracle.prefill(&mut s).map_err(|e| e.to_string())?;
            while !s.is_finished() {
                let mut live = vec![&mut s];
                oracle.decode_step(&mut live).map_err(|e| e.to_string())?;
            }
            oracle.drop_seq(*id);
            if &s.generated != generated {
                return Err(format!(
                    "seq {id} diverged from the standalone oracle: \
                     {:?} vs {:?}",
                    generated, s.generated));
            }
        }
        Ok(())
    });
}

/// Churn under pool PRESSURE (tight block budget, preemption in the
/// mix): the auditor stays green every round, nothing leaks, nothing
/// double-frees, and the drain returns the pool to empty.
#[test]
fn churn_under_pool_pressure_never_leaks_blocks() {
    let rt = runtime();
    property("prefix_pool_pressure", 3, |rng| {
        let vocab = rt.manifest().config("servethin").unwrap().vocab;
        let c = rt.manifest().config("servethin").unwrap();
        let bytes_per_token =
            c.n_layers as f64 * (c.k_cache_dims + c.v_cache_dims) as f64 * 2.0;
        // 24 blocks: a handful of concurrent sequences, so admission
        // blocks, forks fail on a full pool, and retirement/fork/preempt
        // constantly recycle blocks through the free list
        let budget = bytes_per_token * (24.0 * 16.0 + 0.5);
        let eng = engine(&rt, "servethin");
        let kv = kv_for(&rt, "servethin", budget);
        let mut sched = Scheduler::with_config(eng, kv, SchedConfig {
            max_batch: 4,
            prefix_sharing: true,
            ..SchedConfig::default()
        });
        let family = synth_prompt(16, vocab, rng);
        let mut submitted = 0usize;
        let mut forked = 0usize;
        for _ in 0..24 {
            match rng.below(6) {
                0 | 1 => {
                    let mut p = family.clone();
                    p.extend(synth_prompt(2 + rng.below(12), vocab, rng));
                    sched.submit(p, 2 + rng.below(4), None);
                    submitted += 1;
                }
                2 => {
                    let ids = sched.running_ids();
                    if !ids.is_empty()
                        && sched
                            .fork(ids[rng.below(ids.len())], 1 + rng.below(3))
                            .is_ok()
                    {
                        forked += 1;
                    }
                }
                3 if sched.n_running() > 1 => {
                    let _ = sched.preempt_one();
                }
                _ => {}
            }
            // debug test builds audit every round: a refcount leak, an
            // aliased CoW block, or a stale prefix registration fails
            // the step right here
            sched.step().map_err(|e| format!("step failed: {e:#}"))?;
        }
        sched
            .run_to_completion()
            .map_err(|e| format!("drain failed: {e:#}"))?;
        let stats = sched.kv.sharing_stats();
        if stats.blocks_used != 0 {
            return Err(format!(
                "{} blocks leaked after drain", stats.blocks_used));
        }
        let v = sched.kv.refcount_violations();
        if !v.is_empty() {
            return Err(format!("refcount violations after drain: {v:?}"));
        }
        if sched.finished.len() != submitted + forked {
            return Err(format!(
                "{submitted} submitted + {forked} forked but {} accounted \
                 for", sched.finished.len()));
        }
        if sched.engine.metrics.sync_download_bytes != 0 {
            return Err("full-arena download under pressure".into());
        }
        Ok(())
    });
}
