//! `cargo xtask` — repo-local task runner. Std-only so it builds on a bare
//! toolchain (no xla, no workspace) and CI can run it unconditionally.
//!
//! Commands:
//!   lint [--clippy]   custom deny-rules over the serving coordinator
//!                     (plus `cargo clippy -- -D warnings` when the main
//!                     crate's manifest is present and --clippy is given)
//!
//! The lint pass encodes repo-specific invariants that clippy cannot know:
//!
//! - **no-unwrap-in-hot-path** — `coordinator/` is the request-serving hot
//!   path; a stray `.unwrap()` / `panic!(` turns a recoverable scheduling
//!   error into a process abort mid-serve. Errors must be typed
//!   (`anyhow::Result`) or, where the invariant is locally provable,
//!   `.expect("...")` with a message naming the invariant.
//! - **no-hardcoded-elem-size** — byte arithmetic like `* 4` bakes in the
//!   fp32 element size and silently breaks the q8 arena math. All element
//!   sizing goes through `ArenaSizing` / `KvQuant::elem_bytes` /
//!   `size_of::<f32>()`; `metrics.rs` (the `ArenaSizing` home) is the one
//!   blessed location.
//! - **no-lane-enumeration** — lane indices are owned by `LaneMap`
//!   (`lanes.rs`); deriving one positionally (enumerating sequences into
//!   lane slots, or indexing a raw lane vector) bypasses the lane-stability
//!   contract that keeps regroups zero-copy.
//! - **no-naked-anyhow-propagation** — the engine step boundary
//!   (`prefill` / `prefill_chunk` / `decode_step`) returns a typed
//!   `EngineError` so the scheduler can retry, quarantine, or escalate by
//!   CLASS. A naked `?` on a step call erases that classification back
//!   into an anyhow chain and silently opts out of the fault-recovery
//!   policy — step failures must be matched (retry loop) or explicitly
//!   converted.
//! - **no-direct-pool-free** — KV blocks are refcounted; the ONLY legal
//!   way to return one to the pool is the refcount-aware release path in
//!   `kvcache.rs` (`Pool::release` via `KvCacheManager::release` /
//!   `evict_slot`). Touching `pool.free` / `pool.refs` / `pool.release(`
//!   anywhere else (scheduler, engine, router, …) can free a block a
//!   shared-prefix sequence still references — a use-after-free of device
//!   rows. `kvcache.rs` owns the pool; `eviction.rs` is the policy layer
//!   blessed to drive it.
//! - **no-exit-in-recovery** — `supervisor.rs` and `router.rs` are the
//!   crash-recovery path: they exist to turn a Fatal into a warm restart
//!   or a drained report. A `process::exit` there kills the process the
//!   machinery was built to keep alive (and skips destructors holding
//!   device state). Recovery code returns errors; only `main.rs` — outside
//!   the coordinator tree — may exit.
//!
//! Rules scan comment-stripped, string-masked source and skip everything
//! from the first `#[cfg(test)]` to end of file — tests may unwrap freely.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

#[derive(Debug, PartialEq)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    detail: String,
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}:{}: [{}] {}",
            self.file, self.line, self.rule, self.detail
        )
    }
}

/// Replace `//` comments and string-literal *contents* with spaces, keeping
/// line structure and byte offsets stable, so rules never trip on prose
/// (an `.expect("never unwrap here")` message, a doc comment quoting
/// `* 4`). Handles escapes and simple char literals; block comments are
/// not used in this codebase (clippy's `needless_doctest_main` era style).
fn mask_source(text: &str) -> String {
    let b: Vec<char> = text.chars().collect();
    let mut out: Vec<char> = Vec::with_capacity(b.len());
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c == '/' && i + 1 < b.len() && b[i + 1] == '/' {
            // line comment: blank to end of line
            while i < b.len() && b[i] != '\n' {
                out.push(' ');
                i += 1;
            }
        } else if c == '"' {
            // string literal: keep the quotes, blank the contents
            out.push('"');
            i += 1;
            while i < b.len() && b[i] != '"' {
                if b[i] == '\\' && i + 1 < b.len() {
                    // keep escaped newlines (string continuations) so
                    // masked line numbers stay aligned with the source
                    out.push(' ');
                    out.push(if b[i + 1] == '\n' { '\n' } else { ' ' });
                    i += 2;
                    continue;
                }
                out.push(if b[i] == '\n' { '\n' } else { ' ' });
                i += 1;
            }
            if i < b.len() {
                out.push('"');
                i += 1;
            }
        } else if c == '\'' {
            // char literal ('x', '\n', '"') vs lifetime ('a) — a literal
            // closes within 4 chars; lifetimes never close.
            let close = (i + 1..b.len().min(i + 4)).find(|&j| b[j] == '\'');
            match close {
                Some(j) => {
                    out.push('\'');
                    for _ in i + 1..j {
                        out.push(' ');
                    }
                    out.push('\'');
                    i = j + 1;
                }
                None => {
                    out.push(c);
                    i += 1;
                }
            }
        } else {
            out.push(c);
            i += 1;
        }
    }
    out.into_iter().collect()
}

/// Is the `4` at byte-index `pos` of `line` a standalone literal (not part
/// of `42`, `x4`, `4.0`, `_4`)?
fn lone_digit(line: &[u8], pos: usize) -> bool {
    let ok = |c: u8| !(c.is_ascii_alphanumeric() || c == b'_' || c == b'.');
    (pos == 0 || ok(line[pos - 1]))
        && (pos + 1 >= line.len() || ok(line[pos + 1]))
}

/// Lint one coordinator source file. `file_name` is the basename
/// (e.g. `"engine.rs"`); per-file exemptions key off it.
fn lint_source(file_name: &str, text: &str) -> Vec<Violation> {
    let masked = mask_source(text);
    // Everything from the first `#[cfg(test)]` onward is test scaffolding.
    let scan_end = masked.find("#[cfg(test)]").unwrap_or(masked.len());
    let mut out = Vec::new();

    for (ln, line) in masked[..scan_end].lines().enumerate() {
        let lineno = ln + 1;
        let mut fail = |rule: &'static str, detail: String| {
            out.push(Violation {
                file: file_name.to_string(),
                line: lineno,
                rule,
                detail,
            });
        };

        // no-unwrap-in-hot-path
        if line.contains(".unwrap()") {
            fail(
                "no-unwrap-in-hot-path",
                "`.unwrap()` in the serving hot path — return a typed \
                 error, or `.expect(\"<invariant>\")` if locally provable"
                    .into(),
            );
        }
        if line.contains("panic!(") {
            fail(
                "no-unwrap-in-hot-path",
                "`panic!` in the serving hot path — use `anyhow::bail!`"
                    .into(),
            );
        }

        // no-hardcoded-elem-size: `* 4` / `4 *` byte math outside the
        // blessed ArenaSizing home.
        if file_name != "metrics.rs" {
            let bytes = line.as_bytes();
            for (i, w) in bytes.windows(3).enumerate() {
                let hit = (w == b"* 4" && lone_digit(bytes, i + 2))
                    || (w == b"4 *" && lone_digit(bytes, i));
                if hit {
                    fail(
                        "no-hardcoded-elem-size",
                        "hardcoded element-size arithmetic — route byte \
                         math through ArenaSizing / KvQuant::elem_bytes / \
                         size_of"
                            .into(),
                    );
                    break;
                }
            }
        }

        // no-naked-anyhow-propagation: engine step calls return typed
        // EngineError; a `?` on the same line throws the classification
        // away (anyhow's blanket From) and bypasses retry/quarantine.
        // The `_inner`/`_round` helpers don't match — `(` must follow
        // the step name directly.
        let step_call = line.contains(".prefill(")
            || line.contains(".prefill_chunk(")
            || line.contains(".decode_step(");
        if step_call && line.contains(")?") {
            fail(
                "no-naked-anyhow-propagation",
                "engine step error `?`-propagated as anyhow — match the \
                 typed EngineError (retry / quarantine / escalate) \
                 instead of erasing its class"
                    .into(),
            );
        }

        // no-direct-pool-free: the block pool's free list and refcounts
        // are kvcache.rs internals; eviction.rs is the one policy layer
        // blessed to drive the release path. Anything else touching them
        // can free a block a shared-prefix sequence still references.
        if file_name != "kvcache.rs" && file_name != "eviction.rs" {
            let direct = line.contains("pool.free")
                || line.contains("pool.refs")
                || line.contains("pool.release(");
            if direct {
                fail(
                    "no-direct-pool-free",
                    "direct Pool free-list/refcount access — KV blocks go \
                     back to the pool only through the refcount-aware \
                     release path (KvCacheManager::release / evict_slot \
                     in kvcache.rs)"
                        .into(),
                );
            }
        }

        // no-exit-in-recovery: the supervisor/router exist to keep the
        // serve loop alive through Fatal — exiting there defeats the
        // machinery (and skips Drop on live device state).
        if (file_name == "supervisor.rs" || file_name == "router.rs")
            && line.contains("process::exit")
        {
            fail(
                "no-exit-in-recovery",
                "`process::exit` in the crash-recovery path — return a \
                 typed error (RestartBudgetExhausted) and let the router \
                 drain; only main.rs may exit"
                    .into(),
            );
        }

        // no-lane-enumeration: lane indices come from LaneMap only.
        if file_name != "lanes.rs" {
            let positional =
                line.contains(".enumerate()") && line.contains("lane");
            if positional || line.contains(".lanes[") {
                fail(
                    "no-lane-enumeration",
                    "lane index derived positionally — lanes are owned by \
                     LaneMap (`lane_of`, regroup plans); enumerating \
                     sequences into lane slots breaks lane stability"
                        .into(),
                );
            }
        }
    }
    out
}

fn coordinator_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask lives under rust/")
        .join("src")
        .join("coordinator")
}

fn lint_tree() -> Result<Vec<Violation>, String> {
    let dir = coordinator_dir();
    let mut entries: Vec<PathBuf> = std::fs::read_dir(&dir)
        .map_err(|e| format!("cannot read {dir:?}: {e}"))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "rs"))
        .collect();
    entries.sort();
    let mut out = Vec::new();
    for path in entries {
        let name = path
            .file_name()
            .and_then(|n| n.to_str())
            .unwrap_or_default()
            .to_string();
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {path:?}: {e}"))?;
        out.extend(lint_source(&name, &text));
    }
    Ok(out)
}

fn run_clippy() -> Result<bool, String> {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("xtask lives under rust/")
        .join("Cargo.toml");
    if !manifest.exists() {
        println!(
            "xtask lint: {} not tracked; clippy step skipped",
            manifest.display()
        );
        return Ok(true);
    }
    let status = std::process::Command::new("cargo")
        .args(["clippy", "--manifest-path"])
        .arg(&manifest)
        .args(["--all-targets", "--", "-D", "warnings"])
        .status()
        .map_err(|e| format!("cannot spawn cargo clippy: {e}"))?;
    Ok(status.success())
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match argv.first().map(String::as_str) {
        Some("lint") => {
            let violations = match lint_tree() {
                Ok(v) => v,
                Err(e) => {
                    eprintln!("xtask lint: {e}");
                    return ExitCode::FAILURE;
                }
            };
            for v in &violations {
                eprintln!("FAIL {v}");
            }
            let clippy_ok = if argv.iter().any(|a| a == "--clippy") {
                match run_clippy() {
                    Ok(ok) => ok,
                    Err(e) => {
                        eprintln!("xtask lint: {e}");
                        false
                    }
                }
            } else {
                true
            };
            if violations.is_empty() && clippy_ok {
                println!("xtask lint: OK (coordinator deny rules clean)");
                ExitCode::SUCCESS
            } else {
                eprintln!(
                    "xtask lint: {} violation(s){}",
                    violations.len(),
                    if clippy_ok { "" } else { " + clippy failures" }
                );
                ExitCode::FAILURE
            }
        }
        _ => {
            println!("usage: cargo xtask lint [--clippy]");
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(file: &str, src: &str) -> Vec<&'static str> {
        lint_source(file, src).into_iter().map(|v| v.rule).collect()
    }

    // -- seeded violations: every deny rule must catch its fixture --

    #[test]
    fn seeded_unwrap_is_denied() {
        let src = "fn hot(x: Option<u32>) -> u32 { x.unwrap() }\n";
        assert_eq!(rules("engine.rs", src), vec!["no-unwrap-in-hot-path"]);
    }

    #[test]
    fn seeded_panic_is_denied() {
        let src = "fn hot() { panic!(\"bad lane\"); }\n";
        assert_eq!(rules("scheduler.rs", src), vec!["no-unwrap-in-hot-path"]);
    }

    #[test]
    fn seeded_elem_size_star4_is_denied() {
        let src = "fn bytes(rows: usize) -> usize { rows * 4 }\n";
        assert_eq!(rules("engine.rs", src), vec!["no-hardcoded-elem-size"]);
    }

    #[test]
    fn seeded_elem_size_4star_is_denied() {
        let src = "fn bytes(rows: usize) -> usize { 4 * rows }\n";
        assert_eq!(rules("kvcache.rs", src), vec!["no-hardcoded-elem-size"]);
    }

    #[test]
    fn seeded_lane_enumeration_is_denied() {
        let src = "fn pack(ids: &[u64]) {\n    \
                   for (lane, id) in ids.iter().enumerate() { go(lane, id); }\n\
                   }\n";
        assert_eq!(rules("engine.rs", src), vec!["no-lane-enumeration"]);
    }

    #[test]
    fn seeded_raw_lane_index_is_denied() {
        let src = "fn peek(&self) { let x = self.lanes[0]; use_(x); }\n";
        assert_eq!(rules("engine.rs", src), vec!["no-lane-enumeration"]);
    }

    #[test]
    fn seeded_naked_step_propagation_is_denied() {
        let src = "fn go(&mut self) -> Result<()> {\n    \
                   self.engine.decode_step(&mut seqs)?;\n    Ok(())\n}\n";
        assert_eq!(rules("scheduler.rs", src),
                   vec!["no-naked-anyhow-propagation"]);
    }

    #[test]
    fn seeded_naked_prefill_propagation_is_denied() {
        let src = "fn a(&mut self, s: &mut Sequence) -> Result<()> {\n    \
                   self.engine.prefill(s)?;\n    Ok(())\n}\n\
                   fn b(&mut self, s: &mut Sequence) -> Result<bool> {\n    \
                   let done = self.engine.prefill_chunk(s, 16)?;\n    \
                   Ok(done)\n}\n";
        assert_eq!(rules("scheduler.rs", src),
                   vec!["no-naked-anyhow-propagation",
                        "no-naked-anyhow-propagation"]);
    }

    #[test]
    fn seeded_direct_free_list_push_is_denied() {
        let src = "fn shortcut(&mut self, b: BlockId) {\n    \
                   self.kv.pool.free.push(b);\n}\n";
        assert_eq!(rules("scheduler.rs", src), vec!["no-direct-pool-free"]);
    }

    #[test]
    fn seeded_refcount_fiddling_is_denied() {
        let src = "fn drop_ref(&mut self, b: usize) {\n    \
                   self.pool.refs[b] -= 1;\n}\n";
        assert_eq!(rules("engine.rs", src), vec!["no-direct-pool-free"]);
    }

    #[test]
    fn seeded_pool_release_call_is_denied() {
        let src = "fn evict(&mut self, b: BlockId) {\n    \
                   let _ = self.kv.pool.release(b);\n}\n";
        assert_eq!(rules("router.rs", src), vec!["no-direct-pool-free"]);
    }

    #[test]
    fn kvcache_and_eviction_own_the_pool() {
        let src = "fn release(&mut self, b: BlockId) {\n    \
                   if self.pool.release(b) { self.pool.free.len(); }\n}\n";
        assert!(rules("kvcache.rs", src).is_empty());
        assert!(rules("eviction.rs", src).is_empty());
    }

    #[test]
    fn seeded_exit_in_supervisor_is_denied() {
        let src = "fn give_up() -> ! { std::process::exit(1) }\n";
        assert_eq!(rules("supervisor.rs", src), vec!["no-exit-in-recovery"]);
    }

    #[test]
    fn seeded_exit_in_router_is_denied() {
        // a `use` alias does not dodge the rule
        let src = "use std::process;\n\
                   fn bail_out() { process::exit(2); }\n";
        assert_eq!(rules("router.rs", src), vec!["no-exit-in-recovery"]);
    }

    #[test]
    fn exit_outside_the_recovery_path_is_not_this_rules_business() {
        // main.rs lives outside the coordinator tree entirely; within the
        // tree, the rule pins only the two recovery files
        let src = "fn cli_fail() -> ! { std::process::exit(1) }\n";
        assert!(rules("engine.rs", src).is_empty());
    }

    #[test]
    fn matched_step_calls_and_inner_helpers_are_allowed() {
        // closure-wrapped retry calls carry no `?`; the `_inner` split
        // keeps its anyhow plumbing (the `(` must follow the step name)
        let src = "fn ok(&mut self) -> Result<(), EngineError> {\n    \
                   self.with_retries(|eng| eng.prefill(&mut seq))\n}\n\
                   fn inner(&mut self) -> Result<()> {\n    \
                   self.prefill_chunk_inner(seq, chunk)?;\n    \
                   self.decode_step_inner(seqs)?;\n    Ok(())\n}\n";
        assert!(rules("scheduler.rs", src).is_empty());
    }

    // -- exemptions --

    #[test]
    fn metrics_rs_may_do_elem_size_math() {
        let src = "pub fn payload(rows: usize) -> usize { rows * 4 }\n";
        assert!(rules("metrics.rs", src).is_empty());
    }

    #[test]
    fn lanes_rs_owns_lane_enumeration() {
        let src = "fn scan(&self) {\n    \
                   for (lane, s) in self.slots.iter().enumerate() { t(lane, s); }\n\
                   }\n";
        assert!(rules("lanes.rs", src).is_empty());
    }

    // -- false-positive guards --

    #[test]
    fn comments_and_strings_do_not_trip_rules() {
        let src = "// a comment may say .unwrap() or * 4 freely\n\
                   fn ok() -> &'static str { \".unwrap() * 4 panic!(\" }\n";
        assert!(rules("engine.rs", src).is_empty());
    }

    #[test]
    fn unwrap_or_variants_are_allowed() {
        let src = "fn ok(x: Option<u32>) -> u32 { x.unwrap_or(0) }\n\
                   fn ok2(x: Option<u32>) -> u32 { x.unwrap_or_else(|| 1) }\n";
        assert!(rules("engine.rs", src).is_empty());
    }

    #[test]
    fn multi_digit_literals_are_not_elem_sizes() {
        let src = "fn ok(n: usize) -> usize { n * 42 + 14 * n + n * 4096 }\n";
        assert!(rules("engine.rs", src).is_empty());
    }

    #[test]
    fn test_modules_are_skipped() {
        let src = "fn ok() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n    \
                   #[test]\n    \
                   fn t() { Some(3u32).unwrap(); let _ = 2 * 4; }\n\
                   }\n";
        assert!(rules("engine.rs", src).is_empty());
    }

    #[test]
    fn char_literal_quote_does_not_derail_masking() {
        let src = "fn ok(c: char) -> bool { c == '\"' }\n\
                   fn bad(x: Option<u32>) -> u32 { x.unwrap() }\n";
        assert_eq!(rules("engine.rs", src), vec!["no-unwrap-in-hot-path"]);
    }

    #[test]
    fn violation_reports_file_line_and_rule() {
        let src = "fn a() {}\nfn b(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let v = lint_source("router.rs", src);
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].line, 2);
        assert_eq!(
            v[0].to_string().split(": ").next().unwrap(),
            "router.rs:2"
        );
    }

    // -- the real tree must be clean: this IS the lint gate --

    #[test]
    fn coordinator_tree_is_clean() {
        let violations = lint_tree().expect("coordinator sources readable");
        assert!(
            violations.is_empty(),
            "coordinator lint violations:\n{}",
            violations
                .iter()
                .map(|v| format!("  {v}"))
                .collect::<Vec<_>>()
                .join("\n")
        );
    }
}
