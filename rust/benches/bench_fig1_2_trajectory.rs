//! Regenerates paper Figures 1/2 + Tables 3/4/5 (Experiments 7/7b:
//! full-vs-thin from-scratch training trajectories at two token budgets,
//! plus downstream probe parity). Quick budget; full protocol:
//! `thinkeys experiments exp7`.
use thinkeys::experiments::{exp67_llama, Opts};
use thinkeys::runtime::Runtime;

fn main() {
    let rt = Runtime::new().expect("make artifacts first");
    let opts = Opts::quick();
    for t in exp67_llama::tables_3_4_figs(&rt, &opts).unwrap() {
        t.print();
    }
    exp67_llama::table5(&rt, &opts).unwrap().print();
}
