//! Serving-stack benchmark: closed-loop throughput + open-loop latency for
//! full vs factored keys under identical KV budgets, plus the capacity
//! comparison (the paper's "~60% more concurrent users"). Also exercises
//! the Pallas-kernel decode path for the L1 perf comparison.
use thinkeys::analysis::trajectory;
use thinkeys::bench::Table;
use thinkeys::coordinator::engine::Engine;
use thinkeys::coordinator::eviction::EvictionPolicy;
use thinkeys::coordinator::kvcache::{KvCacheConfig, KvCacheManager};
use thinkeys::coordinator::metrics::ServeReport;
use thinkeys::coordinator::router::Router;
use thinkeys::coordinator::sampling::Sampler;
use thinkeys::coordinator::scheduler::{SchedConfig, Scheduler};
use thinkeys::coordinator::supervisor::{Supervisor, SupervisorConfig};
use thinkeys::datagen::arrival::{closed_loop, mixed_chat_doc_trace};
use thinkeys::experiments::serving;
use thinkeys::runtime::{FaultPlan, ParamStore, Runtime};
use thinkeys::substrate::json::{num, obj, s, Value};

/// Append this run's per-config serving numbers to `BENCH_serving.json`
/// at the repo root — the perf trajectory across PRs (ROADMAP open item).
/// Each run entry records throughput, TTFT p50/p99, and the arena gauges
/// per serving config; the file accumulates so a regression shows up as a
/// kink in the series rather than a silent drift. The read/append/write
/// cycle lives in `analysis::trajectory` so the empty-report path is
/// unit-tested in the library.
fn record_trajectory(rows: Vec<Value>) {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("benches live under rust/")
        .join("BENCH_serving.json");
    let unix_time = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0);
    match trajectory::append_run(&path, rows, unix_time) {
        Ok(_) => println!("\nperf trajectory appended to {}", path.display()),
        Err(e) => eprintln!("cannot write {path:?}: {e}"),
    }
}

/// One supervised closed-loop run of the mixed chat+doc workload on
/// servethin (checkpoint every 4 rounds), optionally under a fault plan.
/// Uses its OWN Runtime so an installed plan never leaks into the other
/// benchmark scenarios.
fn supervised_run(plan: Option<FaultPlan>) -> ServeReport {
    let rt = Runtime::new().expect("make artifacts first");
    if let Some(p) = plan {
        rt.install_fault_plan(p);
    }
    let cfg_name = "servethin";
    let cfg = rt.manifest().config(cfg_name).unwrap().clone();
    let params = ParamStore::init(&cfg, 42);
    let eng =
        Engine::new(&rt, cfg_name, params, false, Sampler::Greedy, 0).unwrap();
    let kv = KvCacheManager::new(KvCacheConfig {
        n_layers: cfg.n_layers,
        k_dims: cfg.k_cache_dims,
        v_dims: cfg.v_cache_dims,
        block_tokens: 16,
        bytes_per_el_k: 2.0,
        bytes_per_el_v: 2.0,
        budget_bytes: 4e6,
    });
    let chunk = rt.manifest().chunks_for(cfg_name).first().copied();
    let sched = Scheduler::with_config(eng, kv, SchedConfig {
        max_batch: 8,
        round_budget: 64,
        chunk_tokens: chunk,
        retry_backoff_us: 50,
        ..SchedConfig::default()
    });
    let rt_ref = &rt;
    let fact_cfg = cfg.clone();
    let factory = move || {
        let params = ParamStore::init(&fact_cfg, 42);
        Engine::new(rt_ref, cfg_name, params, false, Sampler::Greedy, 0)
    };
    let scfg = SupervisorConfig {
        checkpoint_every: 4,
        ..SupervisorConfig::default()
    };
    let mut router =
        Router::new(sched).with_supervisor(Supervisor::new(scfg, factory));
    router
        .run_closed_loop(&mixed_chat_doc_trace(10, 3, 0.002, 0.0005), 0)
        .expect("supervised run must survive its fault plan")
}

fn main() {
    let rt = Runtime::new().expect("make artifacts first");
    let mut trajectory: Vec<Value> = Vec::new();
    let mut t = Table::new(
        "Closed-loop serving under a fixed 2 MB KV budget",
        &["config", "tok/s", "concurrent capacity (tokens)", "occupancy",
          "copyback B (vs full repack)", "sync up/down B", "delta B/step"],
    );
    for cfg_name in ["servefull", "servethin", "servegqa", "servegqathin"] {
        let cfg = rt.manifest().config(cfg_name).unwrap().clone();
        let params = ParamStore::init(&cfg, 42);
        let eng = Engine::new(&rt, cfg_name, params, false,
                              Sampler::Greedy, 0).unwrap();
        let kv = KvCacheManager::new(KvCacheConfig {
            n_layers: cfg.n_layers,
            k_dims: cfg.k_cache_dims,
            v_dims: cfg.v_cache_dims,
            block_tokens: 16,
            bytes_per_el_k: 2.0,
            bytes_per_el_v: 2.0,
            budget_bytes: 2e6,
        });
        let capacity = kv.cfg.token_capacity();
        let sched = Scheduler::new(eng, kv, 16);
        let mut router = Router::new(sched);
        let report = router
            .run_closed_loop(&closed_loop(16, 32, 12), 0)
            .unwrap();
        let m = &router.sched.engine.metrics;
        t.row(&[
            cfg_name.to_string(),
            format!("{:.1}", report.gen_tokens_per_sec()),
            capacity.to_string(),
            format!("{:.2}", m.mean_occupancy()),
            format!("{} (vs {})", m.copyback_bytes, m.copyback_bytes_full),
            format!("{}/{}", m.sync_upload_bytes, m.sync_download_bytes),
            format!("{:.0}", m.row_sync_bytes_per_step()),
        ]);
        assert_eq!(m.sync_download_bytes, 0,
                   "full-arena download regression in {cfg_name}");
        trajectory.push(obj(vec![
            ("config", s(cfg_name)),
            ("gen_tok_per_s", num(report.gen_tokens_per_sec())),
            ("ttft_p50_us", num(report.ttft.quantile_us(0.5))),
            ("ttft_p99_us", num(report.ttft.quantile_us(0.99))),
            ("arena_bytes", num(m.arena_bytes as f64)),
            ("arena_k_bytes", num(m.arena_k_bytes as f64)),
            ("row_sync_bytes_per_step", num(m.row_sync_bytes_per_step())),
            ("capacity_tokens", num(capacity as f64)),
            ("occupancy", num(m.mean_occupancy())),
        ]));
    }
    t.print();

    // Supervised warm restart (ISSUE 9): the same mixed workload served
    // fault-free vs under a seeded fatal plan, both supervised. The
    // recovery cost is the TTFT p99 delta + the replayed-token count;
    // the recovered run must still complete everything it was sent.
    let base = supervised_run(None);
    let faulted = supervised_run(Some(FaultPlan {
        seed: 7,
        fatal: 0.02,
        max_burst: 2,
        ..FaultPlan::empty()
    }));
    let mut rtab = Table::new(
        "Supervised restart: fault-free vs seeded fatal plan (servethin)",
        &["scenario", "tok/s", "ttft p99 us", "restarts", "replayed tok",
          "ckpt B"],
    );
    for (name, r) in [("fault-free", &base), ("fatal-plan", &faulted)] {
        rtab.row(&[
            name.to_string(),
            format!("{:.1}", r.gen_tokens_per_sec()),
            format!("{:.0}", r.ttft.quantile_us(0.99)),
            r.recovery.engine_restarts.to_string(),
            r.recovery.replayed_tokens.to_string(),
            r.recovery.checkpoint_bytes.to_string(),
        ]);
    }
    rtab.print();
    assert_eq!(base.recovery.engine_restarts, 0);
    assert!(faulted.recovery.engine_restarts > 0,
            "the seeded fatal plan never exercised a restart");
    assert_eq!(faulted.failed, 0,
               "a supervised run must lose nothing to its fatal plan");
    assert_eq!(faulted.n_requests, base.n_requests);
    let p99_delta = faulted.ttft.quantile_us(0.99)
        - base.ttft.quantile_us(0.99);
    trajectory.push(obj(vec![
        ("config", s("servethin-restart")),
        ("gen_tok_per_s", num(faulted.gen_tokens_per_sec())),
        ("ttft_p99_us", num(faulted.ttft.quantile_us(0.99))),
        ("ttft_p99_delta_us", num(p99_delta)),
        ("engine_restarts", num(faulted.recovery.engine_restarts as f64)),
        ("replayed_tokens", num(faulted.recovery.replayed_tokens as f64)),
        ("checkpoint_bytes", num(faulted.recovery.checkpoint_bytes as f64)),
    ]));

    record_trajectory(trajectory);
    // before/after the context-tiered artifact grid at short contexts —
    // the Eq. 10 bytes-per-step win made visible
    serving::tiered_decode_table(&rt, &thinkeys::experiments::Opts::quick())
        .unwrap()
        .print();
    serving::mixed_length_table(&rt, "servethin").unwrap().print();

    // chunked prefill vs monolithic on the mixed chat+doc trace (ISSUE 3
    // acceptance): interactive decode-TTFT p99 must be STRICTLY lower
    // with chunking — a chat arriving mid-document waits at most one
    // chunk boundary instead of the whole document prompt
    let (chunk_table, p99s) =
        serving::chunked_prefill_table(&rt, "servethin").unwrap();
    chunk_table.print();
    let mono_p99 = p99s
        .iter()
        .find(|(m, _)| m.is_none())
        .map(|&(_, p)| p)
        .expect("monolithic row");
    let best_chunked = p99s
        .iter()
        .filter(|(m, _)| m.is_some())
        .map(|&(_, p)| p)
        .fold(f64::INFINITY, f64::min);
    assert!(
        best_chunked < mono_p99,
        "chunked prefill did not improve interactive TTFT p99: \
         monolithic {mono_p99:.0}us vs best chunked {best_chunked:.0}us"
    );
    serving::regroup_copyback_table(&rt, "servethin").unwrap().print();
    serving::capacity_table().print();

    // Quantized KV cache (ISSUE 4 acceptance): the mixed trace served
    // from int8 arenas must cut K+V arena payload >= 3.9x (exactly 4x at
    // matched bucket/tier trajectories; scale planes reported separately)
    // with decode throughput no worse than fp32 and a tightly bounded
    // teacher-forced logit error. The download tripwire holds in q8 too.
    let (quant_table, qc) =
        serving::quantized_decode_table(&rt, "servethin").unwrap();
    quant_table.print();
    assert!(qc.q8_arena_bytes > 0 && qc.fp32_arena_bytes > 0);
    let arena_ratio = qc.fp32_arena_bytes as f64 / qc.q8_arena_bytes as f64;
    assert!(
        arena_ratio >= 3.9,
        "q8 arena payload reduction below 3.9x: {arena_ratio:.2}x \
         ({} vs {} B)",
        qc.fp32_arena_bytes, qc.q8_arena_bytes
    );
    assert!(
        qc.q8_row_sync_per_step < qc.fp32_row_sync_per_step,
        "q8 per-step delta sync not smaller: {:.0} vs {:.0} B/step",
        qc.q8_row_sync_per_step, qc.fp32_row_sync_per_step
    );
    assert!(
        qc.max_abs_logit_err.is_finite() && qc.max_abs_logit_err < 0.05,
        "q8 logit error out of bounds: {}",
        qc.max_abs_logit_err
    );
    // throughput parity: the q8 artifacts move 4x fewer cache bytes —
    // on bandwidth-bound hardware that is a strict win, but the 1-core
    // CPU/interpret testbed is dispatch- and matmul-bound and pays the
    // int8<->f32 casts in compute, so parity is expected rather than
    // guaranteed. Warn loudly inside the noise band; hard-fail only on
    // a real regression.
    if qc.q8_tok_s < qc.fp32_tok_s {
        eprintln!(
            "WARNING: q8 decode below fp32 on this testbed: {:.1} vs \
             {:.1} tok/s ({:.0}%)",
            qc.q8_tok_s, qc.fp32_tok_s,
            100.0 * qc.q8_tok_s / qc.fp32_tok_s
        );
    }
    assert!(
        qc.q8_tok_s >= 0.85 * qc.fp32_tok_s,
        "q8 decode throughput regressed beyond noise: {:.1} vs {:.1} tok/s",
        qc.q8_tok_s, qc.fp32_tok_s
    );

    // Grouped thin keys (ISSUE 5): the measured composition table — the
    // four serve configs x kv-quant driven through an identical decode
    // trajectory, compression read off the engine's arena_k_bytes gauge.
    // servegqathin-q8 must hold >= 15x less K arena than servefull-fp32
    // (64x payload, 32x with its scale plane at the toy KD=4 width) with
    // the grouped q8 decode logits teacher-forced-bounded.
    let (gqa_table, gc) = serving::gqa_composition_table(&rt).unwrap();
    gqa_table.print();
    assert!(
        gc.composed_key_compression >= 15.0
            && gc.composed_key_compression_with_scales >= 15.0,
        "measured composed key compression below 15x: {:.1}x ({:.1}x with \
         scales)",
        gc.composed_key_compression,
        gc.composed_key_compression_with_scales
    );
    assert!(
        gc.gqa_thin_q8_logit_err.is_finite()
            && gc.gqa_thin_q8_logit_err < 0.05,
        "grouped q8 logit error out of bounds: {}",
        gc.gqa_thin_q8_logit_err
    );

    // Shared-prefix paged KV (ISSUE 8 acceptance): N chat users over ONE
    // system prompt on an identical block pool. With sharing, the prefix
    // prefills exactly once (prefill tokens == unique tokens, prefix_hits
    // == N-1), the pool holds strictly more concurrent users, interactive
    // TTFT p50 is strictly lower, and every user's output is bit-exact vs
    // the sharing-disabled run.
    let (prefix_table, prefix_cmp) =
        serving::shared_prefix_table(&rt, "servethin").unwrap();
    prefix_table.print();
    for c in &prefix_cmp {
        let n = c.users;
        assert!(c.outputs_match(),
                "outputs diverged between sharing modes at N={n}");
        assert_eq!(c.shared.prefill_tokens, c.unique_tokens,
                   "N={n}: shared run computed more than the unique tokens");
        assert_eq!(c.shared.prefix_hits, (n as u64) - 1,
                   "N={n}: every user after the first must adopt the prefix");
        assert_eq!(c.shared.sync_download_bytes, 0);
        assert_eq!(c.unshared.sync_download_bytes, 0);
        assert_eq!(c.unshared.prefix_hits, 0,
                   "sharing disabled but the prefix tree still matched");
    }
    let c8 = prefix_cmp.iter().find(|c| c.users == 8).expect("N=8 row");
    assert!(
        c8.shared.peak_concurrent > c8.unshared.peak_concurrent,
        "sharing must hold strictly more concurrent users on the same \
         pool: {} vs {}",
        c8.shared.peak_concurrent, c8.unshared.peak_concurrent
    );
    assert!(
        c8.shared.report.ttft.quantile_us(0.5)
            < c8.unshared.report.ttft.quantile_us(0.5),
        "sharing must cut interactive TTFT p50: {:.0}us vs {:.0}us",
        c8.shared.report.ttft.quantile_us(0.5),
        c8.unshared.report.ttft.quantile_us(0.5)
    );
    assert!(c8.shared.peak_dedup_bytes > 0.0
                && c8.shared.peak_shared_blocks > 0);

    // Bounded-cache streaming (ISSUE 10 acceptance): the infinite-chat
    // trace — streams whose full reservations each exceed the pool — is
    // rejected wholesale without eviction, and completes wholesale under
    // every active policy while the pool gauge never exceeds the budget,
    // sink + recency slots are never evicted, and the device-residency
    // tripwire holds (eviction zeroes rows host-side and re-uploads;
    // nothing ever downloads).
    let (evict_table, evict_runs) =
        serving::eviction_policy_table(&rt, "servethin").unwrap();
    evict_table.print();
    let none_run = evict_runs
        .iter()
        .find(|r| r.policy == EvictionPolicy::None)
        .expect("policy-none row");
    assert_eq!(
        none_run.completed, 0,
        "the acceptance trace must overwhelm the pool without eviction"
    );
    assert!(none_run.rejected > 0);
    for r in evict_runs.iter().filter(|r| r.policy != EvictionPolicy::None) {
        let p = r.policy.name();
        assert_eq!(r.rejected, 0, "{p}: streams rejected despite eviction");
        assert!(r.completed > 0 && r.report.failed == 0,
                "{p}: streams lost under eviction");
        assert!(
            r.peak_pool_blocks <= r.pool_blocks,
            "{p}: peak pool {} blocks exceeded the {}-block budget",
            r.peak_pool_blocks, r.pool_blocks
        );
        assert_eq!(r.pinning_violations, 0,
                   "{p}: a sink or recency slot was evicted");
        assert!(r.evicted_blocks > 0 && r.capped_admissions > 0,
                "{p}: the bounded trace never exercised eviction");
        assert_eq!(r.sync_download_bytes, 0,
                   "{p}: eviction must not round-trip arenas through host");
    }

    // Thin-vs-full eviction-score fidelity (ISSUE 10): the factored
    // r-dim keys must rank eviction victims like the full d-dim keys do.
    // Hard bounds are sanity only (toy widths); EXPERIMENTS.md records
    // the measured numbers. Skipped on a legacy grid without the
    // attn_mass plane (the policy table already emitted skip rows).
    let has_mass =
        evict_runs.iter().any(|r| r.policy == EvictionPolicy::A2sf);
    if has_mass {
        let (fid_table, fid) = serving::score_fidelity_table(&rt).unwrap();
        fid_table.print();
        assert!(fid.spearman.is_finite()
                    && fid.spearman.abs() <= 1.0 + 1e-9);
        assert!(fid.full_order_delta.is_finite()
                    && fid.thin_order_delta.is_finite());
        assert!(fid.k > 0 && fid.slots >= fid.k);
        if fid.spearman < 0.5 {
            eprintln!(
                "WARNING: thin-vs-full eviction rank correlation low on \
                 this testbed: rho = {:.3}",
                fid.spearman
            );
        }
    } else {
        println!(
            "score fidelity skipped: artifact grid has no attn_mass plane"
        );
    }

    // Pallas-kernel decode path (L1 lowered into the serving HLO)
    let tok_ref = serving::decode_throughput(&rt, "servethin", 8, 10, false)
        .unwrap();
    let tok_pal = serving::decode_throughput(&rt, "servethin", 8, 10, true)
        .unwrap();
    println!("\ndecode b=8: ref-attention {:.1} tok/s vs pallas-kernel \
              {:.1} tok/s (interpret-mode lowering)", tok_ref, tok_pal);
}
