//! Regenerates paper Table 13 (Experiment 2: content-based key-value
//! retrieval by d_select). Quick budget; full protocol:
//! `thinkeys experiments exp2`.
use thinkeys::experiments::{exp2_kvret, Opts};
use thinkeys::runtime::Runtime;

fn main() {
    let rt = Runtime::new().expect("make artifacts first");
    exp2_kvret::run(&rt, &Opts::quick()).unwrap().print();
}
