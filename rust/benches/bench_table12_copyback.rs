//! Regenerates paper Table 12 (Experiment 1: copy-back / positional
//! selection by d_select). Quick budget; the full protocol is
//! `thinkeys experiments exp1`.
use thinkeys::experiments::{exp1_copyback, Opts};
use thinkeys::runtime::Runtime;

fn main() {
    let rt = Runtime::new().expect("make artifacts first");
    exp1_copyback::run(&rt, &Opts::quick()).unwrap().print();
}
