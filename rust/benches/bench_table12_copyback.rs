//! Regenerates paper Table 12 (Experiment 1: copy-back / positional
//! selection by d_select). Quick budget; the full protocol is
//! `thinkeys experiments exp1`. Also reports the serving-side copy-back
//! accounting: host bytes moved by the engine's incremental lane-stable
//! regroup vs the full park/unpark baseline on a steady-state retirement.
use thinkeys::experiments::{exp1_copyback, serving, Opts};
use thinkeys::runtime::Runtime;

fn main() {
    let rt = Runtime::new().expect("make artifacts first");
    exp1_copyback::run(&rt, &Opts::quick()).unwrap().print();
    serving::regroup_copyback_table(&rt, "servethin").unwrap().print();
}
