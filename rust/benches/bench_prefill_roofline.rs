//! §12 prefill analysis: the analytical intensity model plus measured
//! prefill latency of this stack, full vs factored keys (the QK^T FLOP
//! saving shows up as faster prefill when compute-bound).
use thinkeys::bench::{bench, fmt_s, Table};
use thinkeys::coordinator::engine::Engine;
use thinkeys::coordinator::router::synth_prompt;
use thinkeys::coordinator::sampling::Sampler;
use thinkeys::coordinator::sequence::Sequence;
use thinkeys::experiments::analytical;
use thinkeys::runtime::{ParamStore, Runtime};
use thinkeys::substrate::rng::Rng;

fn main() {
    analytical::prefill_roofline().print();
    let rt = Runtime::new().expect("make artifacts first");
    let mut t = Table::new("Measured prefill latency (prompt=120)",
                           &["config", "mean", "p99"]);
    for cfg_name in ["servefull", "servethin"] {
        let cfg = rt.manifest().config(cfg_name).unwrap().clone();
        let params = ParamStore::init(&cfg, 42);
        let mut eng = Engine::new(&rt, cfg_name, params, false,
                                  Sampler::Greedy, 0).unwrap();
        let mut rng = Rng::new(0);
        let mut id = 0u64;
        let st = bench(2, 12, || {
            id += 1;
            let mut seq = Sequence::new(
                id, synth_prompt(120, cfg.vocab, &mut rng), 4, None);
            eng.prefill(&mut seq).unwrap();
            eng.drop_seq(seq.id);
        });
        t.row(&[cfg_name.to_string(), fmt_s(st.mean_s), fmt_s(st.p99_s)]);
    }
    t.print();
}
