//! Regenerates paper Table 10 (KV GB/user at 128K and 1M context), plus
//! the §6 composition column: factored rank x GQA x int8 key-cache
//! compression (the "up to 16x" claim, with per-row scale overhead
//! included — ISSUE 4), and — since the servegqa grid exists (ISSUE 5) —
//! the MEASURED composition table: the same stack read off the engine's
//! arena gauges while it actually serves, not recomputed analytically.
use thinkeys::experiments::{analytical, serving};
use thinkeys::runtime::Runtime;

fn main() {
    analytical::table10().print();
    let comp = analytical::quantized_composition();
    comp.print();
    analytical::prefill_roofline().print();

    // the analytic composition acceptance: r=d/4 x q8 => ~16x key-cache
    // bytes vs the full fp32 baseline; adding GQA (exp8's grouped heads)
    // exceeds it
    let rows = thinkeys::coordinator::roofline::quantized_composition_rows();
    let thin_q8 = rows.iter().find(|r| r.0.contains("r=d/4, q8")).unwrap();
    assert!((thin_q8.2 - 16.0).abs() < 0.1,
            "thin x q8 composition off: {}x", thin_q8.2);
    let gqa_q8 = rows.iter().find(|r| r.0.contains("GQA-8 + thin")).unwrap();
    assert!(gqa_q8.2 > 60.0, "GQA composition off: {}x", gqa_q8.2);

    // the MEASURED composition acceptance (ISSUE 5): the servegqathin-q8
    // engine must hold a K arena >= 15x smaller than servefull-fp32 at
    // identical (bucket, tier) — read from `arena_k_bytes`, the gauge the
    // engine sizes its real storage by — with teacher-forced grouped
    // decode logits within the q8 bound.
    let rt = Runtime::new().expect("make artifacts first (servegqa grid)");
    assert!(
        rt.manifest().configs.contains_key("servegqa"),
        "artifact grid predates the GQA serving configs — re-run \
         `make artifacts` to export the servegqa/servegqathin grid"
    );
    let (table, gc) = serving::gqa_composition_table(&rt).unwrap();
    table.print();
    assert!(
        gc.composed_key_compression >= 15.0,
        "measured composed key compression below 15x: {:.1}x",
        gc.composed_key_compression
    );
    assert!(
        gc.composed_key_compression_with_scales >= 15.0,
        "composed key compression (incl. scale plane) below 15x: {:.1}x",
        gc.composed_key_compression_with_scales
    );
    assert!(
        gc.group_key_compression >= 3.9,
        "pure group factor off: {:.1}x",
        gc.group_key_compression
    );
    assert!(
        gc.gqa_thin_q8_logit_err.is_finite()
            && gc.gqa_thin_q8_logit_err < 0.05,
        "grouped q8 logit error out of bounds: {}",
        gc.gqa_thin_q8_logit_err
    );
}
