//! Regenerates paper Table 10 (KV GB/user at 128K and 1M context).
use thinkeys::experiments::analytical;

fn main() {
    analytical::table10().print();
    analytical::prefill_roofline().print();
}
