//! Regenerates paper Table 10 (KV GB/user at 128K and 1M context), plus
//! the §6 composition column: factored rank x GQA x int8 key-cache
//! compression (the "up to 16x" claim, with per-row scale overhead
//! included — ISSUE 4).
use thinkeys::experiments::analytical;

fn main() {
    analytical::table10().print();
    let comp = analytical::quantized_composition();
    comp.print();
    analytical::prefill_roofline().print();

    // the composition acceptance: r=d/4 x q8 => ~16x key-cache bytes vs
    // the full fp32 baseline; adding GQA (exp8's grouped heads) exceeds it
    let rows = thinkeys::coordinator::roofline::quantized_composition_rows();
    let thin_q8 = rows.iter().find(|r| r.0.contains("r=d/4, q8")).unwrap();
    assert!((thin_q8.2 - 16.0).abs() < 0.1,
            "thin x q8 composition off: {}x", thin_q8.2);
    let gqa_q8 = rows.iter().find(|r| r.0.contains("GQA-8 + thin")).unwrap();
    assert!(gqa_q8.2 > 60.0, "GQA composition off: {}x", gqa_q8.2);
}
