//! Regenerates paper Table 19 + Tables 7/8 (Experiment 8 + §11: SVD+QK-FT
//! on the GQA model and the gsm-mini domain-matched fine-tuning grid).
//! Quick budget; full protocol: `thinkeys experiments exp8 exp19`.
use thinkeys::experiments::{exp19_domain_ft, exp8_gqa, Opts};
use thinkeys::runtime::Runtime;

fn main() {
    let rt = Runtime::new().expect("make artifacts first");
    let opts = Opts::quick();
    for t in exp8_gqa::run(&rt, &opts).unwrap() {
        t.print();
    }
    exp19_domain_ft::run(&rt, &opts).unwrap().print();
}
