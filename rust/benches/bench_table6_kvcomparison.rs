//! Regenerates paper Table 6 (analytical KV comparison @ LLaMA-7B/128K).
//! Exact-number reproduction; also times the calculator itself.
use thinkeys::bench::{bench, fmt_s};
use thinkeys::experiments::analytical;

fn main() {
    analytical::table6().print();
    let st = bench(10, 1000, || {
        let _ = thinkeys::coordinator::roofline::table6_rows();
    });
    println!("\ncalculator: {} per eval", fmt_s(st.mean_s));
}
