//! Regenerates paper Tables 16/17 (Experiment 6: LLaMA-arch d_select sweep
//! + the GQA/MLA from-scratch comparison). Quick budget; full protocol:
//! `thinkeys experiments exp6`.
use thinkeys::experiments::{exp67_llama, Opts};
use thinkeys::runtime::Runtime;

fn main() {
    let rt = Runtime::new().expect("make artifacts first");
    let opts = Opts::quick();
    exp67_llama::table16(&rt, &opts).unwrap().print();
    exp67_llama::table17(&rt, &opts).unwrap().print();
}
