//! Regenerates paper Table 1 (Experiment 5: SVD compression of the
//! pretrained model — Both vs K-only vs Q-only by rank). The shape to
//! confirm: K-only is far more forgiving than Q-only; both compounds.
//! Quick budget; full protocol: `thinkeys experiments exp5`.
use thinkeys::experiments::{exp5_svd, Opts};
use thinkeys::runtime::Runtime;

fn main() {
    let rt = Runtime::new().expect("make artifacts first");
    exp5_svd::table1(&rt, &Opts::quick()).unwrap().print();
}
