//! Regenerates paper Tables 14/15 (Experiments 3/4: d_select sweep in the
//! overfit vs underfit corpus regimes). Quick budget; full protocol:
//! `thinkeys experiments exp34`.
use thinkeys::experiments::{exp34_lm_sweep, Opts};
use thinkeys::runtime::Runtime;

fn main() {
    let rt = Runtime::new().expect("make artifacts first");
    for t in exp34_lm_sweep::run(&rt, &Opts::quick()).unwrap() {
        t.print();
    }
}
