//! Regenerates paper Table 11: Eq. 10 predicted speedups (exact) plus
//! measured decode throughput of this stack at batch 1..32, full vs
//! factored keys. The paper's shape to confirm: speedup monotone in batch.
use thinkeys::experiments::{serving, Opts};
use thinkeys::runtime::Runtime;

fn main() {
    let rt = Runtime::new().expect("make artifacts first");
    let opts = Opts::quick();
    for t in serving::run(&rt, &opts).unwrap() {
        t.print();
    }
}
