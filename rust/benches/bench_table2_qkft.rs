//! Regenerates paper Table 2 (Experiment 5: SVD + QK-only fine-tuning
//! recovery vs identically fine-tuned control). Quick budget; full
//! protocol: `thinkeys experiments exp5`.
use thinkeys::experiments::{exp5_svd, Opts};
use thinkeys::runtime::Runtime;

fn main() {
    let rt = Runtime::new().expect("make artifacts first");
    exp5_svd::table2(&rt, &Opts::quick()).unwrap().print();
}
