PYTHON ?= python3

# Export every entry point to HLO text + manifest.json (incremental: only
# re-lowers artifacts whose content hash changed). This is the only python
# that ever runs; the rust binary is self-contained afterwards. The grid
# includes the q8 decode/prefill-chunk columns (manifest kv_quant) that
# `thinkeys serve --kv-quant q8` and the quantized benches require.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

test-python:
	cd python && $(PYTHON) -m pytest tests -q

# Tier-1 gate (see ROADMAP.md).
tier1:
	cd rust && cargo build --release && cargo test -q

# Static grid audit (ISSUE 6): verify the exported artifact grid without
# executing anything — config algebra, ladders, geometry, quant variants,
# scheduler reachability.
check:
	cd rust && cargo run --release -- check

# Coordinator deny rules (std-only xtask crate; add --clippy once the
# main crate's manifest is tracked).
lint:
	cd rust && cargo xtask lint

.PHONY: artifacts test-python tier1 check lint
